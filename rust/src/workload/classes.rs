//! The nine agent classes of §5.1 with their stage templates and per-stage
//! token-length distributions (Appendix-A style skew-normal fits).
//!
//! Size buckets follow the paper: *small* (EV, FV, CC, ALFWI, KBQAV —
//! < 1 min), *medium* (PE, SC — 1–10 min), *large* (DM, MRS — > 10 min),
//! sampled with probability 72% / 26% / 2%.

/// Agent class (paper Fig. 2 + §5.1 workload list).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum AgentClass {
    /// (a) MapReduce Summarization — large.
    MapReduceSummarization,
    /// (b) Plan-and-Execution (HuggingGPT-style) — medium.
    PlanAndExecution,
    /// (c) Code Checking (FacTool) — small.
    CodeChecking,
    /// (d) Knowledge-Based-QA Verification (FacTool) — small.
    KbqaVerification,
    /// (e) Equation Verification (FacTool) — small.
    EquationVerification,
    /// (f) Fact Verification (ReAct-style) — small.
    FactVerification,
    /// (g) ALFWorld Interaction (ReAct) — small.
    AlfworldInteraction,
    /// (h) Document Merging (Graph-of-Thoughts) — large.
    DocumentMerging,
    /// (i) Self-Consistency (Wang et al.) — medium.
    SelfConsistency,
}

/// Size bucket for the 72/26/2 sampling mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SizeBucket {
    /// JCT < 1 min served alone.
    Small,
    /// JCT 1–10 min served alone.
    Medium,
    /// JCT > 10 min served alone.
    Large,
}

/// Skew-normal parameters for a token-length distribution, truncated to
/// `[min, max]` (Appendix A fits per-stage lengths with skewed Gaussians).
#[derive(Debug, Clone, Copy)]
pub struct LenDist {
    /// Location ξ.
    pub xi: f64,
    /// Scale ω.
    pub omega: f64,
    /// Skew α.
    pub alpha: f64,
    /// Truncation lower bound (tokens).
    pub min: u32,
    /// Truncation upper bound (tokens).
    pub max: u32,
}

impl LenDist {
    /// Const constructor.
    pub const fn new(xi: f64, omega: f64, alpha: f64, min: u32, max: u32) -> Self {
        LenDist { xi, omega, alpha, min, max }
    }
}

/// How many parallel tasks a stage spawns: uniform integer in [lo, hi],
/// optionally scaled by the agent's "input size factor" (larger inputs →
/// more chunks for map-reduce-style agents).
#[derive(Debug, Clone, Copy)]
pub struct FanOut {
    /// Minimum parallel tasks.
    pub lo: u32,
    /// Maximum parallel tasks.
    pub hi: u32,
    /// If true, fan-out scales with the agent input-size factor in [0.5, 2].
    pub scales_with_input: bool,
}

/// One stage template.
#[derive(Debug, Clone, Copy)]
pub struct StageTemplate {
    /// Inference kind label (Appendix-A naming).
    pub kind: &'static str,
    /// Parallel-task count distribution.
    pub fan_out: FanOut,
    /// Prompt-length distribution.
    pub prompt: LenDist,
    /// Decode-length distribution.
    pub decode: LenDist,
}

/// Full class template.
#[derive(Debug, Clone)]
pub struct ClassTemplate {
    /// The class this template builds.
    pub class: AgentClass,
    /// Stage templates in execution order.
    pub stages: &'static [StageTemplate],
    /// Vocabulary theme used to synthesize prompt text (predictor features).
    pub theme: &'static str,
}

const fn fan(lo: u32, hi: u32) -> FanOut {
    FanOut { lo, hi, scales_with_input: false }
}

const fn fan_scaled(lo: u32, hi: u32) -> FanOut {
    FanOut { lo, hi, scales_with_input: true }
}

const MRS_STAGES: [StageTemplate; 2] = [
                    StageTemplate {
                        kind: "generate-summary",
                        fan_out: fan_scaled(8, 14),
                        prompt: LenDist::new(1500.0, 180.0, 3.0, 900, 2200),
                        decode: LenDist::new(260.0, 60.0, 4.0, 120, 520),
                    },
                    StageTemplate {
                        kind: "merge-summaries",
                        fan_out: fan(1, 1),
                        prompt: LenDist::new(1800.0, 250.0, 2.0, 1000, 3000),
                        decode: LenDist::new(380.0, 90.0, 3.0, 150, 700),
                    },
                ];

const PE_STAGES: [StageTemplate; 3] = [
                    StageTemplate {
                        kind: "generate-plan",
                        fan_out: fan(1, 1),
                        prompt: LenDist::new(320.0, 50.0, 2.0, 180, 600),
                        decode: LenDist::new(160.0, 40.0, 3.0, 60, 320),
                    },
                    StageTemplate {
                        kind: "execute-step",
                        fan_out: fan(3, 6),
                        prompt: LenDist::new(420.0, 80.0, 2.5, 200, 800),
                        decode: LenDist::new(240.0, 60.0, 3.0, 80, 480),
                    },
                    StageTemplate {
                        kind: "merge-results",
                        fan_out: fan(1, 1),
                        prompt: LenDist::new(600.0, 100.0, 2.0, 300, 1100),
                        decode: LenDist::new(180.0, 50.0, 3.0, 60, 380),
                    },
                ];

const CC_STAGES: [StageTemplate; 1] = [StageTemplate {
                    kind: "check-snippet",
                    fan_out: fan(2, 4),
                    prompt: LenDist::new(340.0, 60.0, 2.0, 160, 620),
                    decode: LenDist::new(64.0, 18.0, 3.0, 24, 140),
                }];

const KBQAV_STAGES: [StageTemplate; 2] = [
                    StageTemplate {
                        kind: "extract-claims",
                        fan_out: fan(1, 1),
                        prompt: LenDist::new(260.0, 40.0, 2.0, 140, 460),
                        decode: LenDist::new(48.0, 14.0, 3.0, 16, 110),
                    },
                    StageTemplate {
                        kind: "verify-claim",
                        fan_out: fan(2, 5),
                        prompt: LenDist::new(210.0, 35.0, 2.0, 110, 400),
                        decode: LenDist::new(52.0, 16.0, 3.0, 16, 120),
                    },
                ];

const EV_STAGES: [StageTemplate; 1] = [StageTemplate {
                    kind: "verify-equation",
                    fan_out: fan(2, 4),
                    prompt: LenDist::new(130.0, 25.0, 2.0, 60, 260),
                    decode: LenDist::new(40.0, 12.0, 3.0, 12, 96),
                }];

const FV_STAGES: [StageTemplate; 2] = [
                    StageTemplate {
                        kind: "generate-queries",
                        fan_out: fan(1, 1),
                        prompt: LenDist::new(362.0, 7.0, 1.5, 340, 390),
                        decode: LenDist::new(56.0, 14.0, 3.0, 20, 120),
                    },
                    StageTemplate {
                        kind: "verify-fact",
                        fan_out: fan(2, 5),
                        prompt: LenDist::new(240.0, 45.0, 2.0, 120, 440),
                        decode: LenDist::new(60.0, 16.0, 3.0, 20, 130),
                    },
                ];

const ALFWI_STAGES: [StageTemplate; 2] = [
                    StageTemplate {
                        kind: "think-act",
                        fan_out: fan(2, 3),
                        prompt: LenDist::new(170.0, 30.0, 2.0, 90, 320),
                        decode: LenDist::new(30.0, 10.0, 3.0, 10, 72),
                    },
                    StageTemplate {
                        kind: "think-act-2",
                        fan_out: fan(1, 2),
                        prompt: LenDist::new(200.0, 35.0, 2.0, 100, 360),
                        decode: LenDist::new(32.0, 10.0, 3.0, 10, 76),
                    },
                ];

const DM_STAGES: [StageTemplate; 3] = [
                    StageTemplate {
                        kind: "merge-docs",
                        fan_out: fan_scaled(5, 8),
                        prompt: LenDist::new(1400.0, 200.0, 2.5, 800, 2200),
                        decode: LenDist::new(420.0, 90.0, 3.0, 200, 760),
                    },
                    StageTemplate {
                        kind: "score-merge",
                        fan_out: fan_scaled(5, 8),
                        prompt: LenDist::new(650.0, 90.0, 2.0, 350, 1100),
                        decode: LenDist::new(70.0, 18.0, 3.0, 24, 150),
                    },
                    StageTemplate {
                        kind: "final-merge",
                        fan_out: fan(1, 1),
                        prompt: LenDist::new(1200.0, 180.0, 2.0, 700, 2000),
                        decode: LenDist::new(340.0, 80.0, 3.0, 150, 640),
                    },
                ];

const SC_STAGES: [StageTemplate; 1] = [StageTemplate {
                    kind: "reason-path",
                    fan_out: fan(6, 10),
                    prompt: LenDist::new(260.0, 45.0, 2.0, 140, 480),
                    decode: LenDist::new(300.0, 70.0, 3.0, 120, 560),
                }];

impl AgentClass {
    /// All nine classes, paper order.
    pub const ALL: [AgentClass; 9] = [
        AgentClass::MapReduceSummarization,
        AgentClass::PlanAndExecution,
        AgentClass::CodeChecking,
        AgentClass::KbqaVerification,
        AgentClass::EquationVerification,
        AgentClass::FactVerification,
        AgentClass::AlfworldInteraction,
        AgentClass::DocumentMerging,
        AgentClass::SelfConsistency,
    ];

    /// Short tag (e.g. "DM", "MRS").
    pub fn short_name(&self) -> &'static str {
        match self {
            AgentClass::MapReduceSummarization => "MRS",
            AgentClass::PlanAndExecution => "PE",
            AgentClass::CodeChecking => "CC",
            AgentClass::KbqaVerification => "KBQAV",
            AgentClass::EquationVerification => "EV",
            AgentClass::FactVerification => "FV",
            AgentClass::AlfworldInteraction => "ALFWI",
            AgentClass::DocumentMerging => "DM",
            AgentClass::SelfConsistency => "SC",
        }
    }

    /// Parse a short tag.
    pub fn by_short_name(s: &str) -> Option<AgentClass> {
        AgentClass::ALL.into_iter().find(|c| c.short_name().eq_ignore_ascii_case(s))
    }

    /// The class's size bucket.
    pub fn size_bucket(&self) -> SizeBucket {
        match self {
            AgentClass::EquationVerification
            | AgentClass::FactVerification
            | AgentClass::CodeChecking
            | AgentClass::AlfworldInteraction
            | AgentClass::KbqaVerification => SizeBucket::Small,
            AgentClass::PlanAndExecution | AgentClass::SelfConsistency => SizeBucket::Medium,
            AgentClass::DocumentMerging | AgentClass::MapReduceSummarization => SizeBucket::Large,
        }
    }

    /// The stage/fan-out/length template for this class. Length scales are
    /// chosen so small/medium/large agents land in the paper's <1 min /
    /// 1–10 min / >10 min runtime buckets on the llama7b-a100 profile.
    pub fn template(&self) -> ClassTemplate {
        match self {
            // Fig. 2a: split a large file into chunks, summarize each in
            // parallel, then merge (Lin et al. 2024; Lan 2025).
            AgentClass::MapReduceSummarization => ClassTemplate {
                class: *self,
                theme: "summarize document section chapter report article text content paragraph overview",
                stages: &MRS_STAGES,
            },
            // HuggingGPT-style: plan once, execute subtasks in parallel,
            // merge the tool outputs.
            AgentClass::PlanAndExecution => ClassTemplate {
                class: *self,
                theme: "plan task step tool execute model action schedule decompose subtask",
                stages: &PE_STAGES,
            },
            // FacTool code checking: extract claims then run parallel checks.
            AgentClass::CodeChecking => ClassTemplate {
                class: *self,
                theme: "code function test assert bug python compile error snippet return",
                stages: &CC_STAGES,
            },
            // FacTool KBQA verification: one query generation + parallel
            // claim verifications.
            AgentClass::KbqaVerification => ClassTemplate {
                class: *self,
                theme: "knowledge claim evidence verify answer query wiki entity fact source",
                stages: &KBQAV_STAGES,
            },
            // FacTool equation verification: tiny parallel checks.
            AgentClass::EquationVerification => ClassTemplate {
                class: *self,
                theme: "equation math solve verify compute number formula result proof value",
                stages: &EV_STAGES,
            },
            // ReAct fact verification (Appendix A example: generate-queries
            // prompts cluster at 360–380 tokens).
            AgentClass::FactVerification => ClassTemplate {
                class: *self,
                theme: "fact verify search evidence question claim statement true false reference",
                stages: &FV_STAGES,
            },
            // ReAct ALFWorld: a short chain of small think/act inferences;
            // parallelism comes from exploring 2-3 candidate actions.
            AgentClass::AlfworldInteraction => ClassTemplate {
                class: *self,
                theme: "room object goto take open put action observation think household navigate",
                stages: &ALFWI_STAGES,
            },
            // Graph-of-Thoughts document merging (Fig. 2b): parallel merges,
            // each followed by scoring, then a final merge. Large.
            AgentClass::DocumentMerging => ClassTemplate {
                class: *self,
                theme: "merge document combine draft revise score rank candidate version aggregate",
                stages: &DM_STAGES,
            },
            // Self-consistency: sample many reasoning trajectories in
            // parallel; majority vote is local (no merge inference).
            AgentClass::SelfConsistency => ClassTemplate {
                class: *self,
                theme: "reason chain thought answer step solve therefore because consider conclude",
                stages: &SC_STAGES,
            },
        }
    }

    /// Classes in a size bucket.
    pub fn in_bucket(bucket: SizeBucket) -> Vec<AgentClass> {
        AgentClass::ALL.into_iter().filter(|c| c.size_bucket() == bucket).collect()
    }

    /// Position in [`AgentClass::ALL`] (paper order). O(1) — metrics index
    /// per-class deadline counters with this.
    pub fn idx(&self) -> usize {
        match self {
            AgentClass::MapReduceSummarization => 0,
            AgentClass::PlanAndExecution => 1,
            AgentClass::CodeChecking => 2,
            AgentClass::KbqaVerification => 3,
            AgentClass::EquationVerification => 4,
            AgentClass::FactVerification => 5,
            AgentClass::AlfworldInteraction => 6,
            AgentClass::DocumentMerging => 7,
            AgentClass::SelfConsistency => 8,
        }
    }

    /// TTFT SLO (ms), bucketed by agent size: interactive small agents
    /// expect a first token within seconds; batch-flavored large agents
    /// tolerate minutes of queueing (DESIGN.md §15). Drives the
    /// FairBatching TTFT-pressure signal and the deadline-miss metric.
    pub fn ttft_slo_ms(&self) -> f64 {
        match self.size_bucket() {
            SizeBucket::Small => 10_000.0,
            SizeBucket::Medium => 30_000.0,
            SizeBucket::Large => 120_000.0,
        }
    }

    /// p99 inter-token-latency SLO (ms) by size bucket: the streaming
    /// experience budget each running decode is entitled to. The tightest
    /// SLO among running decoders is the FairBatching breach threshold.
    pub fn itl_p99_slo_ms(&self) -> f64 {
        match self.size_bucket() {
            SizeBucket::Small => 150.0,
            SizeBucket::Medium => 250.0,
            SizeBucket::Large => 500.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_classes() {
        assert_eq!(AgentClass::ALL.len(), 9);
        let names: Vec<_> = AgentClass::ALL.iter().map(|c| c.short_name()).collect();
        assert_eq!(names, vec!["MRS", "PE", "CC", "KBQAV", "EV", "FV", "ALFWI", "DM", "SC"]);
    }

    #[test]
    fn bucket_membership_matches_paper() {
        use SizeBucket::*;
        assert_eq!(AgentClass::in_bucket(Small).len(), 5);
        assert_eq!(AgentClass::in_bucket(Medium).len(), 2);
        assert_eq!(AgentClass::in_bucket(Large).len(), 2);
        assert_eq!(AgentClass::MapReduceSummarization.size_bucket(), Large);
        assert_eq!(AgentClass::DocumentMerging.size_bucket(), Large);
        assert_eq!(AgentClass::SelfConsistency.size_bucket(), Medium);
        assert_eq!(AgentClass::KbqaVerification.size_bucket(), Small);
    }

    #[test]
    fn by_short_name_roundtrip() {
        for c in AgentClass::ALL {
            assert_eq!(AgentClass::by_short_name(c.short_name()), Some(c));
        }
        assert_eq!(AgentClass::by_short_name("dm"), Some(AgentClass::DocumentMerging));
        assert_eq!(AgentClass::by_short_name("nope"), None);
    }

    #[test]
    fn templates_are_sane() {
        for c in AgentClass::ALL {
            let t = c.template();
            assert!(!t.stages.is_empty(), "{c:?}");
            for s in t.stages {
                assert!(s.fan_out.lo >= 1 && s.fan_out.hi >= s.fan_out.lo, "{c:?} {}", s.kind);
                assert!(s.prompt.min > 0 && s.prompt.max > s.prompt.min);
                assert!(s.decode.min > 0 && s.decode.max > s.decode.min);
            }
            assert!(!t.theme.is_empty());
        }
    }

    #[test]
    fn slo_targets_follow_size_buckets() {
        for (i, c) in AgentClass::ALL.into_iter().enumerate() {
            assert_eq!(c.idx(), i, "{c:?} idx must match paper order");
            assert!(c.ttft_slo_ms() > 0.0 && c.itl_p99_slo_ms() > 0.0);
        }
        // Tighter buckets get tighter deadlines, monotonically.
        use AgentClass::*;
        assert!(EquationVerification.ttft_slo_ms() < SelfConsistency.ttft_slo_ms());
        assert!(SelfConsistency.ttft_slo_ms() < DocumentMerging.ttft_slo_ms());
        assert!(EquationVerification.itl_p99_slo_ms() < SelfConsistency.itl_p99_slo_ms());
        assert!(SelfConsistency.itl_p99_slo_ms() < DocumentMerging.itl_p99_slo_ms());
    }

    #[test]
    fn every_class_has_parallel_tasks() {
        // Task-parallel agents: at least one stage with potential fan-out > 1.
        for c in AgentClass::ALL {
            let t = c.template();
            assert!(t.stages.iter().any(|s| s.fan_out.hi > 1), "{c:?} has no parallelism");
        }
    }
}
