"""AOT compile path: lower the Layer-2 model (with its Layer-1 Pallas
kernel) to HLO **text** artifacts the Rust runtime loads via PJRT.

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to ../artifacts, gitignored):
  model_config.json       — architecture + artifact inventory
  weights.jtt             — seeded weights ("JTT1" container, sorted names)
  prefill.hlo.txt         — prefill(1 sequence, padded to max_prefill)
  decode_b{B}.hlo.txt     — one decode step per batch-size variant

Parameter convention shared with rust/src/runtime: every entry point takes
the weight arrays first (sorted by name — BTreeMap order in Rust), then its
positional state arguments in the documented order.

Usage: python -m compile.aot [--out-dir ../artifacts] [--seed 0]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

DECODE_BATCHES = [1, 2, 4, 8]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_jtt(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write the JTT1 tensor container (reader: rust/src/util/tensor_file.rs)."""
    entries = []
    blobs = []
    offset = 0
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name])
        if arr.dtype == np.float32:
            dtype = "f32"
        elif arr.dtype == np.int32:
            dtype = "i32"
        else:
            raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
        raw = arr.astype("<" + arr.dtype.str[1:]).tobytes()
        entries.append(
            {
                "name": name,
                "dtype": dtype,
                "shape": list(arr.shape),
                "offset": offset,
                "nbytes": len(raw),
            }
        )
        blobs.append(raw)
        offset += len(raw)
    header = json.dumps({"tensors": entries}, sort_keys=True).encode()
    with open(path, "wb") as f:
        f.write(b"JTT1")
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)


def lower_prefill(cfg: M.ModelConfig):
    """prefill(weights..., tokens[S], seq_len[], block_table[maxp], k_pool, v_pool)"""
    def fn(*args):
        n_w = len(M.weight_names(cfg))
        w_list = list(args[:n_w])
        tokens, seq_len, block_table, k_pool, v_pool = args[n_w:]
        return M.prefill(cfg, w_list, tokens, seq_len, block_table, k_pool, v_pool)

    w_specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for shape in _weight_shapes(cfg)
    ]
    pool = jax.ShapeDtypeStruct(cfg.pool_shape(), jnp.float32)
    specs = w_specs + [
        jax.ShapeDtypeStruct((cfg.max_prefill,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((cfg.max_pages_per_seq,), jnp.int32),
        pool,
        pool,
    ]
    return jax.jit(fn).lower(*specs)


def lower_decode(cfg: M.ModelConfig, batch: int):
    """decode(weights..., tokens[B], positions[B], block_tables[B,maxp], k_pool, v_pool)"""
    def fn(*args):
        n_w = len(M.weight_names(cfg))
        w_list = list(args[:n_w])
        tokens, positions, block_tables, k_pool, v_pool = args[n_w:]
        return M.decode(cfg, w_list, tokens, positions, block_tables, k_pool, v_pool)

    w_specs = [jax.ShapeDtypeStruct(shape, jnp.float32) for shape in _weight_shapes(cfg)]
    pool = jax.ShapeDtypeStruct(cfg.pool_shape(), jnp.float32)
    specs = w_specs + [
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch, cfg.max_pages_per_seq), jnp.int32),
        pool,
        pool,
    ]
    return jax.jit(fn).lower(*specs)


@functools.lru_cache(maxsize=None)
def _weight_shapes_cached(cfg: M.ModelConfig):
    w = M.init_weights(cfg, seed=0)
    return tuple(tuple(w[n].shape) for n in M.weight_names(cfg))


def _weight_shapes(cfg: M.ModelConfig):
    return list(_weight_shapes_cached(cfg))


def build_artifacts(out_dir: str, cfg: M.ModelConfig, seed: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_head": cfg.d_head,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "n_pages": cfg.n_pages,
            "page_size": cfg.page_size,
            "max_pages_per_seq": cfg.max_pages_per_seq,
            "max_prefill": cfg.max_prefill,
            "max_positions": cfg.max_positions,
            "seed": seed,
        },
        "weight_names": M.weight_names(cfg),
        "decode_batches": DECODE_BATCHES,
        "artifacts": {},
    }

    weights = M.init_weights(cfg, seed=seed)
    jtt = os.path.join(out_dir, "weights.jtt")
    write_jtt(jtt, weights)
    manifest["artifacts"]["weights"] = "weights.jtt"
    print(f"wrote {jtt} ({os.path.getsize(jtt)} bytes, {len(weights)} tensors)")

    text = to_hlo_text(lower_prefill(cfg))
    path = os.path.join(out_dir, "prefill.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest["artifacts"]["prefill"] = "prefill.hlo.txt"
    print(f"wrote {path} ({len(text)} chars)")

    for b in DECODE_BATCHES:
        text = to_hlo_text(lower_decode(cfg, b))
        path = os.path.join(out_dir, f"decode_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][f"decode_b{b}"] = f"decode_b{b}.hlo.txt"
        print(f"wrote {path} ({len(text)} chars)")

    cfg_path = os.path.join(out_dir, "model_config.json")
    with open(cfg_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {cfg_path}")
    return manifest


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    build_artifacts(os.path.abspath(args.out_dir), M.ModelConfig(), args.seed)
    return 0


if __name__ == "__main__":
    sys.exit(main())
