#!/usr/bin/env bash
# Kick-tires (artifact-evaluation style): build the release binary, run the
# fast experiments + the cluster scale-out sweep, and collect everything
# under out/. Target: a few minutes on a laptop; no network, no GPU, no
# Python required (simulator paths only — see DESIGN.md §3, substitution T1).
#
# Usage: scripts/kick-tires.sh [--quick] [--agents N] [--seed S]
#
#   --quick   small agent counts (~2 min total) — the CI smoke job's mode;
#             numbers are directionally meaningful but noisier than the
#             full 300-agent run used for EXPERIMENTS.md cells.
set -euo pipefail

cd "$(dirname "$0")/.."
ROOT="$PWD"
AGENTS=300
SEED=42
while [ $# -gt 0 ]; do
  case "$1" in
    --quick) AGENTS=40; shift ;;
    --agents) AGENTS="$2"; shift 2 ;;
    --seed) SEED="$2"; shift 2 ;;
    *) echo "unknown flag $1" >&2; exit 2 ;;
  esac
done

echo "== Kick Tires: Justitia reproduction =="
echo "[1/13] cargo build --release"
(cd rust && cargo build --release)
BIN="$ROOT/rust/target/release/justitia"

echo "[2/13] simlint determinism-contract gate"
# Blocking, same as CI: unannotated unordered iteration / ambient
# nondeterminism / NaN-unsafe ordering / knob-default drift all fail the
# run. The last line is the summary CI also surfaces.
(cd rust && cargo run -q -p simlint)

rm -rf out
mkdir -p out
# ResultsFile writes under ./results relative to the cwd.
cd "$ROOT"
rm -rf results
mkdir -p results

echo "[3/13] paper experiments (figs 3, 7-13, table 1) — $AGENTS agents, seed $SEED"
"$BIN" experiment all --agents "$AGENTS" --seed "$SEED"

echo "[4/13] cluster scale-out sweep (1/2/4/8 replicas x 4 placements)"
"$BIN" cluster --agents "$AGENTS" --seed "$SEED"
mv results/cluster.txt results/cluster_sweep.txt

echo "[5/13] prefix-sharing sweep (radix-tree KV dedup off vs on)"
# `experiment all` above already ran the sweep with these arguments; only
# re-run if its JSON artifact is somehow missing.
if [ ! -f results/prefix_sharing.json ]; then
  "$BIN" experiment prefix_sharing --agents "$AGENTS" --seed "$SEED"
fi

echo "[6/13] DAG-agents sweep (map-reduce/tree/pipeline, correction off vs on)"
if [ ! -f results/dag_agents.json ]; then
  "$BIN" experiment dag_agents --agents "$AGENTS" --seed "$SEED"
fi

echo "[7/13] chunked-prefill sweep (chunk x budget vs atomic admission)"
if [ ! -f results/chunked_prefill.json ]; then
  "$BIN" experiment chunked_prefill --agents "$AGENTS" --seed "$SEED"
fi

echo "[8/13] fairbatching sweep (batch policy x scheduler x workload)"
if [ ! -f results/fairbatching.json ]; then
  "$BIN" experiment fairbatching --agents "$AGENTS" --seed "$SEED"
fi

echo "[9/13] preemption sweep (host tier x mode x victim)"
if [ ! -f results/preemption.json ]; then
  "$BIN" experiment preemption --agents "$AGENTS" --seed "$SEED"
fi

echo "[10/13] elasticity sweep (replica churn vs schedule-aware oracle)"
if [ ! -f results/elasticity.json ]; then
  "$BIN" experiment elasticity --agents "$AGENTS" --seed "$SEED"
fi

echo "[11/13] event-core mega scale-out (1M agents, 64 replicas, all cores)"
# ISSUE 6 acceptance: the event-driven core + parallel replica simulation
# push cluster_scaleout to 1M agents across 64 replicas inside the smoke
# budget. Single job => run_suite_parallel hands every core to the replicas.
"$BIN" cluster --agents 1000000 --replicas 64 --placement round-robin \
  --event-core --density 3 --seed "$SEED"
mv results/cluster.txt results/cluster_mega.txt

echo "[12/13] engine hot-path bench (events/sec at 10k and 100k agents)"
# No JUSTITIA_BENCH_BASELINE here: the regression gate runs in the dedicated
# bench-engine CI job; the smoke run only emits the artifact.
(cd rust && cargo bench --bench bench_engine_hot_path)
cp rust/results/BENCH_engine.json results/BENCH_engine.json

echo "[13/13] collecting outputs under out/"
# Fail LOUDLY when an expected artifact is missing (a bare `cp` miss used to
# surface only later as a confusing CI upload error), naming the artifact
# and listing what the run actually produced.
collect() { # collect <produced> <collected-as>
  if [ ! -f "$1" ]; then
    echo "ERROR: expected artifact $1 was not produced by this run" >&2
    echo "results/ contains:" >&2
    ls -l results/ >&2 || true
    exit 1
  fi
  cp "$1" "$2"
}
cp results/*.txt out/
collect results/prefix_sharing.json out/BENCH_prefix.json
collect results/dag_agents.json out/BENCH_dag.json
collect results/chunked_prefill.json out/BENCH_chunked.json
collect results/fairbatching.json out/BENCH_fairbatch.json
collect results/preemption.json out/BENCH_preempt.json
collect results/elasticity.json out/BENCH_elastic.json
collect results/BENCH_engine.json out/BENCH_engine.json
collect results/TRACE_starvation.json out/TRACE_starvation.json
{
  echo "kick-tires run: agents=$AGENTS seed=$SEED date=$(date -u +%Y-%m-%dT%H:%M:%SZ)"
  echo "binary: $BIN"
  "$BIN" help 2>/dev/null | head -3 || true
} > out/MANIFEST.txt

echo
echo "Done. Outputs:"
ls -l out/
echo
echo "Transcribe the numbers into EXPERIMENTS.md (paper-vs-measured tables);"
echo "load out/TRACE_starvation.json in Perfetto (see EXPERIMENTS.md, 'How to"
echo "read a trace')."
