//! Fig. 7 — average and P90 JCT for 300 mixed agents across three backend
//! profiles × six schedulers × three workload densities.
//!
//! Paper headline: Justitia cuts average JCT 57.5% vs VTC and 61.1% vs
//! Parrot, and tracks SRJF (near-optimal efficiency).

use justitia::config::{BackendProfile, Policy};
use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("Fig. 7: JCT across backends x schedulers x densities (300 agents)");
    let mut out = ResultsFile::new("bench_fig7.txt");
    let backends = [
        BackendProfile::llama7b_a100(),
        BackendProfile::llama13b_4v100(),
        BackendProfile::qwen32b_h800(),
    ];
    let rows = justitia::experiments::fig7(&backends, &[1.0, 2.0, 3.0], 300, 42);
    out.line(format!(
        "{:<16} {:>7} {:<10} {:>9} {:>9} {:>5}",
        "backend", "density", "policy", "avgJCT", "p90JCT", "done"
    ));
    for r in &rows {
        out.line(format!(
            "{:<16} {:>6}x {:<10} {:>8.1}s {:>8.1}s {:>5}",
            r.backend,
            r.density,
            r.policy.name(),
            r.avg_jct,
            r.p90_jct,
            r.completed
        ));
    }
    // Headline ratios on the Fig. 7a testbed at 3x.
    let get = |p: Policy| {
        rows.iter()
            .find(|r| r.backend == "llama7b-a100" && r.density == 3.0 && r.policy == p)
            .unwrap()
            .avg_jct
    };
    out.line(format!(
        "llama7b@3x: Justitia vs VTC {:.1}% better (paper 57.5%); vs Parrot {:.1}% (paper 61.1%); vs SRJF {:+.1}%",
        (1.0 - get(Policy::Justitia) / get(Policy::Vtc)) * 100.0,
        (1.0 - get(Policy::Justitia) / get(Policy::AgentFcfs)) * 100.0,
        (get(Policy::Justitia) / get(Policy::Srjf) - 1.0) * 100.0,
    ));
}
