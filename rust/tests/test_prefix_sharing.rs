//! End-to-end prefix-sharing acceptance (ISSUE 2):
//!
//! * enabled on a shared-prefix workload, the cache produces hits, skips
//!   prefill work, and leaves every KV invariant intact (refcounts exact,
//!   pool conserved);
//! * the `prefix_sharing` experiment reports hit rate > 0, strictly fewer
//!   prefill tokens executed than the no-sharing run, and a max-min
//!   fair-share ratio vs GPS no worse than without sharing;
//! * cache-enabled runs are exactly reproducible (same seed → same JCTs);
//! * prefix-affinity placement keeps families on their home replicas while
//!   completing everything.

use justitia::config::{Config, Policy, WorkloadConfig};
use justitia::cost;
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::experiments::{prefix_sharing, rate_scale};
use justitia::workload::trace;

fn shared_cfg(n_agents: usize, seed: u64, cache: bool) -> Config {
    let mut cfg = Config::default();
    cfg.workload = WorkloadConfig { n_agents, seed, ..Default::default() }
        .with_density(3.0)
        .with_shared_prefix(4, 512);
    cfg.prefix_cache = cache;
    cfg
}

fn run_engine(cfg: &Config) -> Engine<SimBackend> {
    let suite = trace::build_suite(&cfg.workload);
    let costs = cost::shared_agent_costs(&suite);
    let sched = justitia::sched::build(Policy::Justitia, cfg.backend.kv_tokens, rate_scale(cfg));
    let mut engine = Engine::new(cfg, sched, SimBackend::new(&cfg.backend));
    engine.run_suite(&suite, |a| costs[&a.id]);
    engine
}

#[test]
fn cache_hits_skip_prefill_and_preserve_invariants() {
    let cfg = shared_cfg(80, 7, true);
    let engine = run_engine(&cfg);
    let m = &engine.metrics;
    assert_eq!(m.completed_agents(), 80, "dropped agents");
    assert!(m.prefix_lookups() > 0);
    assert!(m.prefix_hits() > 0, "families of 4 with 512-token prefixes must hit");
    assert!(m.prefix_hit_rate() > 0.0);
    assert!(m.prefill_tokens_saved() > 0);
    assert!(m.cache_pages_peak() > 0);
    // Page accounting stays exact with the tree's pins declared.
    engine.check_kv_invariants().unwrap();
    assert_eq!(engine.kv.device_tokens(), 0, "device pool not drained");
    // The cache never outgrows the pool.
    let cache = engine.prefix_cache().unwrap();
    assert!(cache.cached_pages() as u64 <= engine.kv.total_pages() as u64);
}

#[test]
fn cache_enabled_runs_are_reproducible() {
    let a = run_engine(&shared_cfg(60, 21, true));
    let b = run_engine(&shared_cfg(60, 21, true));
    assert_eq!(a.metrics.jcts(), b.metrics.jcts(), "cache-enabled replay diverged");
    assert_eq!(a.metrics.prefix_hits(), b.metrics.prefix_hits());
    assert_eq!(a.metrics.prefill_tokens_executed(), b.metrics.prefill_tokens_executed());
}

#[test]
fn experiment_meets_acceptance_bars() {
    let rows = prefix_sharing(&Config::default(), 80, 3.0, 4, 512, 42);
    let (off, on) = (&rows[0], &rows[1]);
    assert_eq!(off.completed, 80);
    assert_eq!(on.completed, 80);
    assert!(on.hit_rate > 0.0, "hit rate must be positive");
    assert!(
        on.prefill_tokens_executed < off.prefill_tokens_executed,
        "prefill executed must drop: {} (on) vs {} (off)",
        on.prefill_tokens_executed,
        off.prefill_tokens_executed
    );
    assert!(
        on.maxmin_ratio <= off.maxmin_ratio * 1.10,
        "fair-share ratio regressed: {} (on) vs {} (off)",
        on.maxmin_ratio,
        off.maxmin_ratio
    );
}

#[test]
fn prefix_affinity_cluster_serves_family_workload() {
    use justitia::cluster::Placement;
    use justitia::experiments::build_sim_cluster;

    let mut cfg = shared_cfg(48, 5, true);
    cfg.cluster.replicas = 4;
    cfg.cluster.placement = Placement::PrefixAffinity;
    let suite = trace::build_suite(&cfg.workload);
    let costs = cost::shared_agent_costs(&suite);
    let mut cluster = build_sim_cluster(&cfg, Policy::Justitia);
    cluster.run_suite(&suite, |a| costs[&a.id]);
    let m = cluster.merged_metrics();
    assert_eq!(m.completed_agents(), 48);
    // Families stay together...
    let mut homes = std::collections::HashMap::new();
    for a in &suite.agents {
        let g = a.prefix_group_id().unwrap();
        let r = cluster.replica_of(a.id).unwrap();
        assert_eq!(*homes.entry(g).or_insert(r), r, "family {g} split");
    }
    // ...which turns later family members into cache hits.
    assert!(m.prefix_hits() > 0);
    for r in 0..cluster.n_replicas() {
        cluster.replica(r).check_kv_invariants().unwrap();
    }
}
