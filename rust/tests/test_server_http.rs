//! HTTP front-end over the real PJRT model: spin the server on a test port,
//! drive it over TCP, and assert end-to-end completion. Skipped when
//! artifacts are absent.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

const PORT: u16 = 18933;

fn artifacts_present() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/model_config.json")
        .exists()
}

fn http(method: &str, path: &str, body: &str) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(("127.0.0.1", PORT))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let status: u16 = resp.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let body_start = resp.find("\r\n\r\n").map(|i| i + 4).unwrap_or(0);
    Ok((status, resp[body_start..].to_string()))
}

#[test]
fn serve_submit_poll_complete() {
    if !artifacts_present() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    std::thread::spawn(move || {
        let _ = justitia::server::http::serve(
            &dir,
            PORT,
            justitia::config::Policy::Justitia,
            1,
            justitia::cluster::Placement::ClusterVtime,
            false,
            Some((4, 65536)), // exercise the flight recorder + /trace end to end
        );
    });

    // Readiness.
    let mut up = false;
    for _ in 0..150 {
        std::thread::sleep(Duration::from_millis(200));
        if matches!(http("GET", "/healthz", ""), Ok((200, _))) {
            up = true;
            break;
        }
    }
    assert!(up, "server did not start");

    // Bad submissions rejected.
    let (s, _) = http("POST", "/agents", "garbage").unwrap();
    assert_eq!(s, 400);
    let (s, _) = http("GET", "/agents/12345", "").unwrap();
    assert_eq!(s, 404);

    // Two tiny agents with explicit stages (sized for the artifact model).
    let a = r#"{"class": "EV", "stages": [[{"p": 8, "d": 4}, {"p": 10, "d": 3}]]}"#;
    let b = r#"{"class": "SC", "stages": [[{"p": 6, "d": 5}], [{"p": 12, "d": 4}]]}"#;
    let (s, body) = http("POST", "/agents", a).unwrap();
    assert_eq!(s, 202, "{body}");
    assert!(body.contains("\"predicted_cost\""));
    let (s, _) = http("POST", "/agents", b).unwrap();
    assert_eq!(s, 202);

    // Poll for completion (metrics are Prometheus text now).
    let t0 = std::time::Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(300));
        let (s, m) = http("GET", "/metrics", "").unwrap();
        assert_eq!(s, 200);
        if m.contains("justitia_agents_completed 2") {
            break;
        }
        // Skip (not fail) on very slow machines.
        if t0.elapsed() > Duration::from_secs(90) {
            panic!("agents did not complete in time: {m}");
        }
    }
    let (s, body) = http("GET", "/agents/0", "").unwrap();
    assert_eq!(s, 200);
    assert!(body.contains("\"done\":true"), "{body}");
    assert!(body.contains("\"jct_s\""));

    // The idle engine thread publishes the merged Chrome dump; allow a few
    // polls for the refresh to land after the last completion.
    let mut trace = String::new();
    for _ in 0..50 {
        let (s, body) = http("GET", "/trace", "").unwrap();
        if s == 200 {
            trace = body;
            break;
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    assert!(trace.contains("\"traceEvents\""), "no trace published: {trace}");
    assert!(trace.contains("first_token"), "trace missing lifecycle events");
}
