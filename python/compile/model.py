"""Layer-2 JAX model: a tiny decoder-only transformer with a PAGED KV cache.

Architecture (real, weights seeded — substitution T6 in DESIGN.md):
  token embedding + learned positional embedding
  n_layers × [RMSNorm → multi-head attention → RMSNorm → GeLU MLP]
  final RMSNorm → tied unembedding

The KV cache is the vLLM-style paged pool the whole paper is about:
`k_pool/v_pool: [n_layers, n_pages+1, page_size, n_heads, d_head]`, where
page index `n_pages` is a trash page absorbing writes from padding positions.
The Rust engine owns the block tables; `prefill` and `decode` take them as
inputs and return updated pools. `decode`'s attention is the Layer-1 Pallas
paged-attention kernel, so it lowers into the same HLO module.

Both entry points are pure functions lowered once by `aot.py` to HLO text
and executed from Rust via PJRT — Python never runs at serving time.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.paged_attention import paged_attention
from .kernels.ref import masked_causal_attention_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 2048
    d_model: int = 128
    n_heads: int = 4
    d_head: int = 32
    n_layers: int = 2
    d_ff: int = 512
    n_pages: int = 64          # real pages (the Rust allocator's pool)
    page_size: int = 16
    max_pages_per_seq: int = 8
    max_prefill: int = 64      # padded prefill length
    max_positions: int = 1024

    @property
    def trash_page(self) -> int:
        return self.n_pages

    def pool_shape(self):
        return (self.n_layers, self.n_pages + 1, self.page_size, self.n_heads, self.d_head)


# Weight-name order is the AOT parameter convention: sorted names here must
# match the sorted-key order the Rust runtime reads from weights.jtt.
def weight_names(cfg: ModelConfig) -> List[str]:
    names = ["embed", "pos_embed", "ln_f"]
    for l in range(cfg.n_layers):
        names += [
            f"layer{l:02d}.ln1",
            f"layer{l:02d}.wqkv",
            f"layer{l:02d}.wo",
            f"layer{l:02d}.ln2",
            f"layer{l:02d}.w_up",
            f"layer{l:02d}.w_down",
        ]
    return sorted(names)


def init_weights(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Seeded random init (no network access for real checkpoints)."""
    rng = np.random.default_rng(seed)

    def dense(shape, scale=None):
        scale = scale or (1.0 / np.sqrt(shape[0]))
        return (rng.standard_normal(shape) * scale).astype(np.float32)

    w: Dict[str, np.ndarray] = {
        "embed": dense((cfg.vocab, cfg.d_model), 0.02),
        "pos_embed": dense((cfg.max_positions, cfg.d_model), 0.02),
        "ln_f": np.ones((cfg.d_model,), np.float32),
    }
    for l in range(cfg.n_layers):
        p = f"layer{l:02d}."
        w[p + "ln1"] = np.ones((cfg.d_model,), np.float32)
        w[p + "wqkv"] = dense((cfg.d_model, 3 * cfg.n_heads * cfg.d_head))
        w[p + "wo"] = dense((cfg.n_heads * cfg.d_head, cfg.d_model))
        w[p + "ln2"] = np.ones((cfg.d_model,), np.float32)
        w[p + "w_up"] = dense((cfg.d_model, cfg.d_ff))
        w[p + "w_down"] = dense((cfg.d_ff, cfg.d_model))
    return w


def weights_as_list(cfg: ModelConfig, w: Dict[str, np.ndarray]) -> List[np.ndarray]:
    return [w[n] for n in weight_names(cfg)]


def _wdict(cfg: ModelConfig, w_list) -> Dict[str, jnp.ndarray]:
    return dict(zip(weight_names(cfg), w_list))


def rms_norm(x, gain):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * gain


def _qkv(cfg: ModelConfig, w, l, x):
    """Project to q, k, v, each [..., H, D]."""
    p = f"layer{l:02d}."
    qkv = x @ w[p + "wqkv"]  # [..., 3*H*D]
    new_shape = qkv.shape[:-1] + (3, cfg.n_heads, cfg.d_head)
    qkv = qkv.reshape(new_shape)
    return qkv[..., 0, :, :], qkv[..., 1, :, :], qkv[..., 2, :, :]


def _mlp(cfg: ModelConfig, w, l, x):
    p = f"layer{l:02d}."
    return jax.nn.gelu(x @ w[p + "w_up"]) @ w[p + "w_down"]


def prefill(cfg: ModelConfig, w_list, tokens, seq_len, block_table, k_pool, v_pool):
    """Prefill ONE sequence (B=1 padded to max_prefill).

    Args:
      tokens:      [S] int32, right-padded with 0.
      seq_len:     [] int32, true prompt length (<= S).
      block_table: [max_pages_per_seq] int32 page ids for this sequence.
      k_pool/v_pool: paged pools (see ModelConfig.pool_shape).

    Returns:
      (logits [vocab] for the last real token, k_pool, v_pool)
    """
    w = _wdict(cfg, w_list)
    s = tokens.shape[0]
    positions = jnp.arange(s)
    x = w["embed"][tokens] + w["pos_embed"][positions]

    # Paged write targets for every position; padding goes to the trash page.
    page_idx = positions // cfg.page_size
    offs = positions % cfg.page_size
    valid = positions < seq_len
    page_ids = jnp.where(valid, block_table[page_idx], cfg.trash_page)

    for l in range(cfg.n_layers):
        p = f"layer{l:02d}."
        h = rms_norm(x, w[p + "ln1"])
        q, k, v = _qkv(cfg, w, l, h)  # [S, H, D]
        k_pool = k_pool.at[l, page_ids, offs].set(k)
        v_pool = v_pool.at[l, page_ids, offs].set(v)
        # Full-sequence causal attention over the in-flight activations
        # (prefill never needs the pool — it IS the context).
        attn = masked_causal_attention_ref(q, k, v, seq_len)
        x = x + attn.reshape(s, cfg.n_heads * cfg.d_head) @ w[p + "wo"]
        x = x + _mlp(cfg, w, l, rms_norm(x, w[p + "ln2"]))

    x = rms_norm(x, w["ln_f"])
    last = x[jnp.maximum(seq_len - 1, 0)]
    logits = last @ w["embed"].T
    return logits, k_pool, v_pool


def decode(cfg: ModelConfig, w_list, tokens, positions, block_tables, k_pool, v_pool):
    """One decode step for a batch of B sequences.

    Args:
      tokens:       [B] int32 last generated token per sequence.
      positions:    [B] int32 position of `tokens` in each sequence
                    (so the context length after this step is positions+1).
      block_tables: [B, max_pages_per_seq] int32.
      k_pool/v_pool: paged pools.

    Returns:
      (logits [B, vocab], k_pool, v_pool)
    """
    w = _wdict(cfg, w_list)
    b = tokens.shape[0]
    x = w["embed"][tokens] + w["pos_embed"][positions]  # [B, dm]
    seq_lens = positions + 1

    batch = jnp.arange(b)
    page_ids = block_tables[batch, positions // cfg.page_size]
    offs = positions % cfg.page_size

    for l in range(cfg.n_layers):
        p = f"layer{l:02d}."
        h = rms_norm(x, w[p + "ln1"])
        q, k, v = _qkv(cfg, w, l, h)  # [B, H, D]
        k_pool = k_pool.at[l, page_ids, offs].set(k)
        v_pool = v_pool.at[l, page_ids, offs].set(v)
        # Layer-1 Pallas kernel: paged attention over the pool.
        attn = paged_attention(q, k_pool[l], v_pool[l], block_tables, seq_lens)
        x = x + attn.reshape(b, cfg.n_heads * cfg.d_head) @ w[p + "wo"]
        x = x + _mlp(cfg, w, l, rms_norm(x, w[p + "ln2"]))

    x = rms_norm(x, w["ln_f"])
    logits = x @ w["embed"].T
    return logits, k_pool, v_pool


def decode_ref(cfg: ModelConfig, w_list, tokens, positions, block_tables, k_pool, v_pool):
    """decode() with the attention swapped for the pure-jnp oracle — the
    L2-level correctness check (pytest asserts decode == decode_ref)."""
    from .kernels.ref import paged_attention_ref

    w = _wdict(cfg, w_list)
    b = tokens.shape[0]
    x = w["embed"][tokens] + w["pos_embed"][positions]
    seq_lens = positions + 1
    batch = jnp.arange(b)
    page_ids = block_tables[batch, positions // cfg.page_size]
    offs = positions % cfg.page_size
    for l in range(cfg.n_layers):
        p = f"layer{l:02d}."
        h = rms_norm(x, w[p + "ln1"])
        q, k, v = _qkv(cfg, w, l, h)
        k_pool = k_pool.at[l, page_ids, offs].set(k)
        v_pool = v_pool.at[l, page_ids, offs].set(v)
        attn = paged_attention_ref(q, k_pool[l], v_pool[l], block_tables, seq_lens)
        x = x + attn.reshape(b, cfg.n_heads * cfg.d_head) @ w[p + "wo"]
        x = x + _mlp(cfg, w, l, rms_norm(x, w[p + "ln2"]))
    x = rms_norm(x, w["ln_f"])
    return x @ w["embed"].T, k_pool, v_pool


def empty_pools(cfg: ModelConfig):
    shape = cfg.pool_shape()
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)
