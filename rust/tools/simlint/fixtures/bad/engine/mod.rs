// Fixture: known-bad core module. Every site here must be flagged.
use std::collections::{HashMap, HashSet};

pub struct Engine {
    agents: HashMap<u32, u64>,
    live: HashSet<u32>,
}

impl Engine {
    // R1: `for` over an unordered map field.
    pub fn total(&self) -> u64 {
        let mut sum = 0;
        for (_, v) in &self.agents {
            sum += v;
        }
        sum
    }

    // R1: `.keys()` / `.iter()` on unordered collections.
    pub fn ids(&self) -> Vec<u32> {
        self.agents.keys().copied().collect()
    }

    pub fn live_ids(&self) -> Vec<u32> {
        self.live.iter().copied().collect()
    }

    // R1: `.drain()` on a local bound to a hash collection.
    pub fn flush(&mut self) -> usize {
        let mut pending: HashMap<u32, u64> = HashMap::new();
        std::mem::swap(&mut pending, &mut self.agents);
        pending.drain().count()
    }

    // R2: wall-clock read on the replay path.
    pub fn stamp(&self) -> std::time::Instant {
        std::time::Instant::now()
    }
}
