//! Substrate utilities built from scratch for this offline image (no rand /
//! serde / tokio / criterion / clap crates available): PRNG + distributions,
//! JSON, descriptive statistics, a thread pool, a criterion-style bench
//! harness, a miniature property-testing framework, and a tensor-file reader
//! for the weight artifact emitted by `python/compile/aot.py`.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tensor_file;
pub mod threadpool;
