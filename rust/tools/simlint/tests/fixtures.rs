//! Fixture corpus for simlint: the `fixtures/bad` tree must flag every
//! planted violation with the right rule id and file:line, and the
//! `fixtures/good` tree must come back clean (annotated sites counted as
//! allowed, not violated). This is the ISSUE-10 acceptance test that
//! `cargo run -p simlint` "fails (nonzero, file:line diagnostics) on each
//! fixture violation".

use simlint::{run, Options};
use std::path::PathBuf;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name)
}

fn has(diags: &[simlint::rules::Diag], file: &str, rule: &str, needle: &str) -> bool {
    diags
        .iter()
        .any(|d| d.file == file && d.rule == rule && d.msg.contains(needle))
}

#[test]
fn bad_tree_flags_every_planted_violation() {
    let report = run(&Options {
        root: fixture("bad"),
        manifest: Some(fixture("bad").join("bad.manifest")),
    })
    .expect("scan bad fixture tree");

    let v = &report.violations;

    // R1: four unordered-iteration sites in engine/.
    assert!(has(v, "engine/mod.rs", "unordered-iter", "`for` over unordered `agents`"));
    assert!(has(v, "engine/mod.rs", "unordered-iter", "`.keys()`) over unordered `agents`"));
    assert!(has(v, "engine/mod.rs", "unordered-iter", "`.iter()`) over unordered `live`"));
    assert!(has(v, "engine/mod.rs", "unordered-iter", "`.drain()`) over unordered `pending`"));

    // R2: wall-clock in engine/, env + RNG in sched/.
    assert!(has(v, "engine/mod.rs", "ambient-nondet", "Instant::now"));
    assert!(has(v, "sched/mod.rs", "ambient-nondet", "std::env"));
    assert!(has(v, "sched/mod.rs", "ambient-nondet", "thread_rng"));

    // R3: the bare partial_cmp, plus the annotation with no justification.
    assert!(has(v, "sched/mod.rs", "nan-order", "partial_cmp"));
    assert!(has(v, "sched/mod.rs", "nan-order", "no justification"));

    // R4: mismatch, unregistered knob, and orphan manifest entry.
    assert!(has(v, "config/mod.rs", "knob-default", "knob `fairness`"));
    assert!(has(v, "config/mod.rs", "knob-default", "`new_feature` is not registered"));
    assert!(has(v, "bad.manifest", "knob-default", "knob `removed_knob`"));

    assert_eq!(v.len(), 12, "exact count pins false-positive drift: {:#?}", v);

    // The stale own-line annotation above `noop()` warns without blocking.
    assert_eq!(report.stale.len(), 1, "{:#?}", report.stale);
    assert!(report.stale[0].msg.contains("unordered-iter"));
    assert!(report.allowed.is_empty(), "{:#?}", report.allowed);

    // Every diagnostic renders as file:line with a rule id.
    for d in v {
        let r = d.render();
        assert!(r.contains(&format!("{}:{}: simlint[", d.file, d.line)), "{r}");
    }
}

#[test]
fn bad_tree_diagnostics_carry_real_lines() {
    let report = run(&Options {
        root: fixture("bad"),
        manifest: Some(fixture("bad").join("bad.manifest")),
    })
    .expect("scan bad fixture tree");
    // Spot-check two pinned locations so line accounting cannot quietly
    // regress: the `for` loop in engine/mod.rs and the sort in sched/mod.rs.
    assert!(report
        .violations
        .iter()
        .any(|d| d.file == "engine/mod.rs" && d.rule == "unordered-iter" && d.line == 13));
    assert!(report
        .violations
        .iter()
        .any(|d| d.file == "sched/mod.rs" && d.rule == "nan-order" && d.line == 5));
}

#[test]
fn good_tree_is_clean_with_annotations_counted() {
    let report = run(&Options {
        root: fixture("good"),
        manifest: Some(fixture("good").join("good.manifest")),
    })
    .expect("scan good fixture tree");

    assert!(report.violations.is_empty(), "{:#?}", report.violations);
    assert!(report.stale.is_empty(), "{:#?}", report.stale);
    // Two justified annotations in engine/ (own-line + same-line forms).
    assert_eq!(report.allowed.len(), 2, "{:#?}", report.allowed);
    assert_eq!(report.files_scanned, 4);
    assert!(report.summary().contains("0 violations"));
}

#[test]
fn exempt_paths_not_scanned_for_core_rules() {
    // util/bench.rs in the good tree is full of Instant::now /
    // available_parallelism / hash iteration — all exempt by path.
    let report = run(&Options { root: fixture("good").join("util"), manifest: None })
        .expect("scan util subtree");
    assert!(report.violations.is_empty(), "{:#?}", report.violations);
}

#[test]
fn real_crate_is_violation_free() {
    // The tree itself must hold the contract: zero unannotated violations
    // against the committed knob manifest. This is the blocking CI gate
    // exercised as a plain test so `cargo test -p simlint` alone proves it.
    let tool_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let report = run(&Options {
        root: tool_dir.join("../../src"),
        manifest: Some(tool_dir.join("knob_defaults.manifest")),
    })
    .expect("scan rust/src");
    let rendered: Vec<String> = report.violations.iter().map(|d| d.render()).collect();
    assert!(report.violations.is_empty(), "determinism contract violations:\n{}", rendered.join("\n"));
    assert!(report.stale.is_empty(), "stale allow annotations: {:#?}", report.stale);
}
