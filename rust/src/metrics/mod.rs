//! Run metrics: JCT, finish-time fair ratios, KV occupancy timelines,
//! scheduling-decision latency (paper §5 metrics).

use crate::util::stats::{self, Welford};
use crate::workload::{AgentClass, AgentId, TaskId};
use std::collections::HashMap;
use std::time::Duration;

/// Buckets of [`LatencyHist`]: 1 µs × 1.1^i, i < 160 (≈ 3.9 s top bucket).
const LATENCY_BUCKETS: usize = 160;
/// Smallest distinguishable latency (s) — everything below lands in bucket 0.
const LATENCY_X0: f64 = 1e-6;
/// Geometric bucket growth: ~10% relative resolution per bucket.
const LATENCY_GROWTH: f64 = 1.1;

/// Fixed log-spaced latency histogram: constant memory, exact merges, and
/// percentile estimates at ~10% relative resolution. Used for the decode
/// inter-token latency distribution (DESIGN.md §10), where storing every
/// (iteration × decoder) sample at paper scale would be megabytes per run.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    counts: [u64; LATENCY_BUCKETS],
    total: u64,
    sum: f64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { counts: [0; LATENCY_BUCKETS], total: 0, sum: 0.0 }
    }
}

impl LatencyHist {
    fn bucket(x: f64) -> usize {
        if x <= LATENCY_X0 {
            return 0;
        }
        (((x / LATENCY_X0).ln() / LATENCY_GROWTH.ln()) as usize).min(LATENCY_BUCKETS - 1)
    }

    /// Record `weight` samples of value `x` seconds.
    pub fn record(&mut self, x: f64, weight: u64) {
        if weight == 0 {
            return;
        }
        self.counts[Self::bucket(x)] += weight;
        self.total += weight;
        self.sum += x * weight as f64;
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean of the recorded samples (exact — tracked outside the buckets).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Percentile estimate, `q` in [0, 100]: the geometric midpoint of the
    /// bucket holding the rank-`q` sample (0 when empty).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return LATENCY_X0 * LATENCY_GROWTH.powf(i as f64 + 0.5);
            }
        }
        LATENCY_X0 * LATENCY_GROWTH.powf(LATENCY_BUCKETS as f64)
    }

    /// Fold another histogram into this one (bucket-exact).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
    }
}

/// Metrics collected over one engine run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    arrival: HashMap<AgentId, f64>,
    complete: HashMap<AgentId, f64>,
    task_admit: HashMap<TaskId, f64>,
    task_complete: HashMap<TaskId, f64>,
    /// When each task became ready (dependencies met / spawned) — the TTFT
    /// anchor: queueing delay counts toward the first token.
    task_ready: HashMap<TaskId, f64>,
    iterations: u64,
    total_prefill_seqs: u64,
    total_decode_seqs: u64,
    engine_time: f64,
    swap_outs: u64,
    /// Recompute preemptions: victims whose KV was discarded instead of
    /// swapped (DESIGN.md §11).
    recompute_drops: u64,
    /// Wasted-token gauge: KV tokens discarded by recompute preemptions,
    /// all of which must be re-prefilled (minus whatever the prefix cache
    /// still covers at re-entry).
    recomputed_tokens: u64,
    /// Prompt tokens actually prefilled (shared-prefix tokens excluded).
    prefill_tokens_executed: u64,
    /// Prefix-cache lookups at admission (0 when the cache is disabled).
    prefix_lookups: u64,
    /// Admissions that matched at least one cached page.
    prefix_hits: u64,
    /// Prompt tokens whose prefill was skipped via the prefix cache.
    prefill_tokens_saved: u64,
    /// Peak number of pages held by the prefix cache.
    cache_pages_peak: u64,
    /// Host-side scheduling decision latency (Fig. 12): wall-clock time the
    /// scheduler spends per decision point.
    sched_latency: Welford,
    /// Tasks emitted at runtime by agents' spawn rules (DAG workloads).
    spawned_tasks: u64,
    /// §4.2 online-correction error statistics (|Ĉ' − C_true| / C_true).
    correction_error: Welford,
    /// Correction error trace: (engine time, relative error) per correction
    /// event, in time order.
    correction_trace: Vec<(f64, f64)>,
    /// Decode inter-token latency: every decoding sequence experiences its
    /// iteration's wall time as the gap between consecutive output tokens.
    decode_itl: LatencyHist,
    /// Time to first token per task, anchored at task readiness (so
    /// scheduler queueing delay is included — the fairness-visible part).
    ttft: LatencyHist,
    /// Prefill-pending sequences that received no chunk in an iteration
    /// because the token budget was spent or no KV page could be acquired
    /// (chunked prefill only — always 0 with the flag off, where a pending
    /// prefill always runs whole).
    prefill_stalls: u64,
    /// (engine time, device tokens, per-agent tokens) — Fig. 3 timeline.
    pub kv_samples: Vec<KvSample>,
    /// Replica crashes this run absorbed (churn runs only, DESIGN.md §14).
    replicas_lost: u64,
    /// In-flight agents salvaged from crashed replicas and re-placed.
    recovered_agents: u64,
    /// Device+host KV tokens destroyed by crashes — all of which the
    /// recovered agents must re-prefill on their new replica (the churn
    /// analogue of `recomputed_tokens`).
    rescheduled_tokens: u64,
    /// Per-class SLO deadline hit/miss counters (DESIGN.md §15), indexed by
    /// [`AgentClass::idx`]. Arrays, not maps: the engine records one ITL
    /// verdict per decoder per iteration, so this sits on the hot path.
    deadlines: [ClassDeadlines; 9],
}

/// SLO deadline counters for one agent class: TTFT deadlines are judged
/// once per task (at first token), ITL deadlines once per decoder per
/// iteration against the class's p99 budget.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassDeadlines {
    /// First-token events judged against the class TTFT SLO.
    pub ttft_total: u64,
    /// ... of which missed the deadline.
    pub ttft_miss: u64,
    /// Decoder-iterations judged against the class p99-ITL SLO.
    pub itl_total: u64,
    /// ... of which exceeded the budget.
    pub itl_miss: u64,
}

impl ClassDeadlines {
    /// Fold another class's counters in (cluster merge).
    fn add(&mut self, other: &ClassDeadlines) {
        self.ttft_total += other.ttft_total;
        self.ttft_miss += other.ttft_miss;
        self.itl_total += other.itl_total;
        self.itl_miss += other.itl_miss;
    }

    /// Miss rate over all judged deadlines (0 when nothing was judged).
    pub fn miss_rate(&self) -> f64 {
        let total = self.ttft_total + self.itl_total;
        if total == 0 {
            0.0
        } else {
            (self.ttft_miss + self.itl_miss) as f64 / total as f64
        }
    }
}

/// One KV-occupancy sample (Fig. 3 timeline).
#[derive(Debug, Clone)]
pub struct KvSample {
    /// Engine time (s).
    pub t: f64,
    /// Tokens resident on device.
    pub device_tokens: u64,
    /// Per-agent resident tokens (sorted by agent).
    pub per_agent: Vec<(AgentId, u64)>,
}

impl RunMetrics {
    /// Empty metrics.
    pub fn new() -> Self {
        Self::default()
    }

    // ---- recording hooks (called by the engine) -------------------------

    /// Record an agent arrival.
    pub fn on_agent_arrival(&mut self, agent: AgentId, t: f64) {
        self.arrival.insert(agent, t);
    }

    /// Record an agent completion.
    pub fn on_agent_complete(&mut self, agent: AgentId, t: f64) {
        self.complete.insert(agent, t);
    }

    /// Record a task admission.
    pub fn on_task_admitted(&mut self, task: TaskId, t: f64) {
        self.task_admit.insert(task, t);
    }

    /// Record a task becoming ready (the TTFT anchor).
    pub fn on_task_ready(&mut self, task: TaskId, t: f64) {
        self.task_ready.insert(task, t);
    }

    /// Record a task's first output token: TTFT = `t` − ready time. The
    /// engine guarantees at most one call per task (preemption re-entries
    /// do not re-fire). Returns the recorded TTFT (s) so the engine can
    /// judge the class deadline and feed the batch-policy loop without
    /// recomputing the ready anchor.
    pub fn on_first_token(&mut self, task: TaskId, t: f64) -> Option<f64> {
        let &ready = self.task_ready.get(&task)?;
        let ttft = (t - ready).max(0.0);
        self.ttft.record(ttft, 1);
        Some(ttft)
    }

    /// Record one TTFT deadline verdict for `class`.
    pub fn on_ttft_deadline(&mut self, class: AgentClass, miss: bool) {
        let d = &mut self.deadlines[class.idx()];
        d.ttft_total += 1;
        d.ttft_miss += miss as u64;
    }

    /// Record `total` decoder-iterations of `class`, `miss` of which
    /// exceeded the class's p99-ITL budget.
    pub fn on_itl_deadlines(&mut self, class: AgentClass, total: u64, miss: u64) {
        let d = &mut self.deadlines[class.idx()];
        d.itl_total += total;
        d.itl_miss += miss;
    }

    /// Record a task completion.
    pub fn on_task_complete(&mut self, task: TaskId, t: f64) {
        self.task_complete.insert(task, t);
    }

    /// Record one engine iteration. `prefill_tokens` is the number of prompt
    /// tokens actually run through the model this iteration (cached-prefix
    /// tokens excluded).
    pub fn on_iteration(
        &mut self,
        now: f64,
        elapsed: f64,
        prefill: usize,
        decode: usize,
        prefill_tokens: u64,
    ) {
        self.iterations += 1;
        self.total_prefill_seqs += prefill as u64;
        self.total_decode_seqs += decode as u64;
        self.prefill_tokens_executed += prefill_tokens;
        self.engine_time = now;
        self.decode_itl.record(elapsed, decode as u64);
    }

    /// Record one prefix-cache admission lookup: `matched_tokens` prompt
    /// tokens were served from cached pages (0 = miss).
    pub fn on_prefix_lookup(&mut self, matched_tokens: u64) {
        self.prefix_lookups += 1;
        if matched_tokens > 0 {
            self.prefix_hits += 1;
            self.prefill_tokens_saved += matched_tokens;
        }
    }

    /// Record the prefix cache's current page occupancy (peak gauge).
    pub fn on_cache_occupancy(&mut self, pages: u64) {
        self.cache_pages_peak = self.cache_pages_peak.max(pages);
    }

    /// Record a preemption swap-out.
    pub fn on_swap_out(&mut self, _task: TaskId, _t: f64) {
        self.swap_outs += 1;
    }

    /// Record a recompute preemption dropping `tokens` of computed KV.
    pub fn on_recompute_drop(&mut self, _task: TaskId, _t: f64, tokens: u64) {
        self.recompute_drops += 1;
        self.recomputed_tokens += tokens;
    }

    /// Record one dynamically-spawned task.
    pub fn on_task_spawned(&mut self) {
        self.spawned_tasks += 1;
    }

    /// Record `n` prefill-pending sequences left without a chunk this
    /// iteration (token budget spent / no KV page available).
    pub fn on_prefill_stalls(&mut self, n: u64) {
        self.prefill_stalls += n;
    }

    /// Record one §4.2 online-correction event with its relative error
    /// against the ground-truth end-to-end cost.
    pub fn on_cost_correction(&mut self, t: f64, rel_err: f64) {
        self.correction_error.push(rel_err);
        self.correction_trace.push((t, rel_err));
    }

    /// Record one scheduling decision's host latency.
    pub fn record_sched_decision(&mut self, d: Duration) {
        self.sched_latency.push(d.as_secs_f64());
    }

    /// Record a KV-occupancy sample.
    pub fn sample_kv(&mut self, t: f64, device_tokens: u64, per_agent: Vec<(AgentId, u64)>) {
        self.kv_samples.push(KvSample { t, device_tokens, per_agent });
    }

    /// Record a replica crash (churn runs, DESIGN.md §14): `recovered`
    /// in-flight agents were salvaged for re-placement and `tokens` of their
    /// KV (device + host) were destroyed. The churn driver books this on the
    /// crashed replica's metrics before graveyarding them, so cluster merges
    /// aggregate churn the same way they aggregate every other counter.
    pub fn on_replica_lost(&mut self, recovered: u64, tokens: u64) {
        self.replicas_lost += 1;
        self.recovered_agents += recovered;
        self.rescheduled_tokens += tokens;
    }

    // ---- derived quantities ---------------------------------------------

    /// Agents completed so far.
    pub fn completed_agents(&self) -> usize {
        self.complete.len()
    }

    /// Engine iterations executed.
    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    /// Final engine clock (s).
    pub fn engine_time(&self) -> f64 {
        self.engine_time
    }

    /// Swap-outs performed.
    pub fn swap_out_count(&self) -> u64 {
        self.swap_outs
    }

    /// Recompute preemptions performed (0 unless a bounded host pool or a
    /// recompute/auto preemption mode forced KV drops).
    pub fn recompute_count(&self) -> u64 {
        self.recompute_drops
    }

    /// KV tokens discarded by recompute preemptions (the wasted-token
    /// gauge: work the engine will re-run as prefill).
    pub fn recomputed_tokens(&self) -> u64 {
        self.recomputed_tokens
    }

    /// Tasks emitted at runtime by spawn rules.
    pub fn spawned_tasks(&self) -> u64 {
        self.spawned_tasks
    }

    /// Prefill-chunk stall events (0 unless chunked prefill ran).
    pub fn prefill_stalls(&self) -> u64 {
        self.prefill_stalls
    }

    /// Replica crashes absorbed (0 unless a churn schedule ran).
    pub fn replicas_lost(&self) -> u64 {
        self.replicas_lost
    }

    /// In-flight agents salvaged from crashed replicas and re-placed.
    pub fn recovered_agents(&self) -> u64 {
        self.recovered_agents
    }

    /// KV tokens destroyed by replica crashes (to be re-prefilled).
    pub fn rescheduled_tokens(&self) -> u64 {
        self.rescheduled_tokens
    }

    /// Aggregate SLO deadline-miss rate across every class and both
    /// deadline kinds (0 when no deadline was ever judged — e.g. runs
    /// without class annotations).
    pub fn deadline_miss_rate(&self) -> f64 {
        let (mut miss, mut total) = (0u64, 0u64);
        for d in &self.deadlines {
            miss += d.ttft_miss + d.itl_miss;
            total += d.ttft_total + d.itl_total;
        }
        if total == 0 {
            0.0
        } else {
            miss as f64 / total as f64
        }
    }

    /// Per-class deadline counters, paper order, classes with at least one
    /// judged deadline only.
    pub fn class_deadlines(&self) -> Vec<(AgentClass, ClassDeadlines)> {
        AgentClass::ALL
            .into_iter()
            .map(|c| (c, self.deadlines[c.idx()]))
            .filter(|(_, d)| d.ttft_total + d.itl_total > 0)
            .collect()
    }

    /// Decode inter-token latency samples recorded (decoders × iterations).
    pub fn decode_itl_samples(&self) -> u64 {
        self.decode_itl.count()
    }

    /// Mean decode inter-token latency (s).
    pub fn decode_itl_mean(&self) -> f64 {
        self.decode_itl.mean()
    }

    /// Decode inter-token latency percentile, `q` in [0, 100] (s) — the
    /// chunked-prefill experiment's tail metric (p99).
    pub fn decode_itl_percentile(&self, q: f64) -> f64 {
        self.decode_itl.percentile(q)
    }

    /// TTFT samples recorded (one per task that produced a token).
    pub fn ttft_samples(&self) -> u64 {
        self.ttft.count()
    }

    /// Mean time to first token (s), queueing delay included.
    pub fn ttft_mean(&self) -> f64 {
        self.ttft.mean()
    }

    /// TTFT percentile, `q` in [0, 100] (s).
    pub fn ttft_percentile(&self, q: f64) -> f64 {
        self.ttft.percentile(q)
    }

    /// Number of §4.2 correction events recorded.
    pub fn correction_samples(&self) -> u64 {
        self.correction_error.count()
    }

    /// Mean relative error of corrected cost estimates vs ground truth
    /// (0 when correction never ran).
    pub fn correction_error_mean(&self) -> f64 {
        if self.correction_error.count() == 0 {
            0.0
        } else {
            self.correction_error.mean()
        }
    }

    /// The correction-error trace: (engine time, relative error) per event.
    pub fn correction_trace(&self) -> &[(f64, f64)] {
        &self.correction_trace
    }

    /// Prompt tokens actually prefilled (cached-prefix tokens excluded).
    pub fn prefill_tokens_executed(&self) -> u64 {
        self.prefill_tokens_executed
    }

    /// Prefix-cache admission lookups.
    pub fn prefix_lookups(&self) -> u64 {
        self.prefix_lookups
    }

    /// Admissions that hit at least one cached page.
    pub fn prefix_hits(&self) -> u64 {
        self.prefix_hits
    }

    /// Hit rate over admission lookups (0 when the cache never ran).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    /// Prompt tokens whose prefill was skipped via the prefix cache.
    pub fn prefill_tokens_saved(&self) -> u64 {
        self.prefill_tokens_saved
    }

    /// Peak pages held by the prefix cache over the run.
    pub fn cache_pages_peak(&self) -> u64 {
        self.cache_pages_peak
    }

    /// Arrival time of an agent.
    pub fn agent_arrival_time(&self, agent: AgentId) -> Option<f64> {
        self.arrival.get(&agent).copied()
    }

    /// Completion time of an agent.
    pub fn agent_complete_time(&self, agent: AgentId) -> Option<f64> {
        self.complete.get(&agent).copied()
    }

    /// Admission time of a task.
    pub fn task_admit_time(&self, task: TaskId) -> Option<f64> {
        self.task_admit.get(&task).copied()
    }

    /// Completion time of a task.
    pub fn task_complete_time(&self, task: TaskId) -> Option<f64> {
        self.task_complete.get(&task).copied()
    }

    /// Job completion time of one agent.
    pub fn jct(&self, agent: AgentId) -> Option<f64> {
        Some(self.complete.get(&agent)? - self.arrival.get(&agent)?)
    }

    /// All JCTs, ordered by agent id.
    pub fn jcts(&self) -> Vec<(AgentId, f64)> {
        let mut v: Vec<(AgentId, f64)> = self
            .complete
            .iter() // simlint::allow(unordered-iter): collected then re-sorted by agent id below
            .filter_map(|(a, &c)| self.arrival.get(a).map(|&ar| (*a, c - ar)))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Average JCT (s).
    pub fn avg_jct(&self) -> f64 {
        let v: Vec<f64> = self.jcts().into_iter().map(|(_, j)| j).collect();
        stats::mean(&v)
    }

    /// P90 JCT (s).
    pub fn p90_jct(&self) -> f64 {
        self.percentile_jct(90.0)
    }

    /// P99 JCT (s) — the cluster scale-out experiment's tail metric.
    pub fn p99_jct(&self) -> f64 {
        self.percentile_jct(99.0)
    }

    /// Arbitrary JCT percentile, `q` in [0, 100].
    pub fn percentile_jct(&self, q: f64) -> f64 {
        let v: Vec<f64> = self.jcts().into_iter().map(|(_, j)| j).collect();
        stats::percentile(&v, q)
    }

    /// Fold another run's metrics into this one. Used by the cluster
    /// dispatcher to merge per-replica metrics into cluster totals; agent
    /// and task ids must be disjoint (each agent runs on exactly one
    /// replica) — except under churn, where a recovered agent appears on
    /// both its crashed and its recovery replica: the driver merges
    /// graveyard metrics first, so later (live-replica) entries win the
    /// per-key maps and JCTs stay anchored at the original arrival
    /// (DESIGN.md §14). Engine time becomes the max (cluster makespan);
    /// counters add; scheduling-latency statistics combine exactly.
    pub fn merge(&mut self, other: &RunMetrics) {
        self.arrival.extend(&other.arrival);
        self.complete.extend(&other.complete);
        self.task_admit.extend(&other.task_admit);
        self.task_complete.extend(&other.task_complete);
        self.task_ready.extend(&other.task_ready);
        self.iterations += other.iterations;
        self.total_prefill_seqs += other.total_prefill_seqs;
        self.total_decode_seqs += other.total_decode_seqs;
        self.engine_time = self.engine_time.max(other.engine_time);
        self.swap_outs += other.swap_outs;
        self.recompute_drops += other.recompute_drops;
        self.recomputed_tokens += other.recomputed_tokens;
        // Prefix-cache counters add across replicas; the occupancy gauge is
        // a peak, so it maxes (each replica has its own cache).
        self.prefill_tokens_executed += other.prefill_tokens_executed;
        self.prefix_lookups += other.prefix_lookups;
        self.prefix_hits += other.prefix_hits;
        self.prefill_tokens_saved += other.prefill_tokens_saved;
        self.cache_pages_peak = self.cache_pages_peak.max(other.cache_pages_peak);
        self.sched_latency.merge(&other.sched_latency);
        self.spawned_tasks += other.spawned_tasks;
        self.decode_itl.merge(&other.decode_itl);
        self.ttft.merge(&other.ttft);
        self.prefill_stalls += other.prefill_stalls;
        self.correction_error.merge(&other.correction_error);
        self.correction_trace.extend(other.correction_trace.iter().copied());
        self.correction_trace.sort_by(|a, b| a.0.total_cmp(&b.0));
        self.kv_samples.extend(other.kv_samples.iter().cloned());
        self.kv_samples.sort_by(|a, b| a.t.total_cmp(&b.t));
        self.replicas_lost += other.replicas_lost;
        self.recovered_agents += other.recovered_agents;
        self.rescheduled_tokens += other.rescheduled_tokens;
        for (mine, theirs) in self.deadlines.iter_mut().zip(other.deadlines.iter()) {
            mine.add(theirs);
        }
    }

    /// Mean scheduling-decision latency in milliseconds (Fig. 12).
    pub fn sched_latency_ms(&self) -> f64 {
        self.sched_latency.mean() * 1e3
    }

    /// Worst-case scheduling decision latency (ms).
    pub fn sched_latency_max_ms(&self) -> f64 {
        self.sched_latency.max() * 1e3
    }

    /// Number of scheduling decisions measured.
    pub fn sched_decisions(&self) -> u64 {
        self.sched_latency.count()
    }
}

/// Finish-time fair ratios (Fig. 8): each agent's JCT under a scheduler
/// normalized by its JCT under the fairness baseline run (the paper uses
/// VTC). Ratio ≤ 1 means the agent finished no later than under the
/// baseline.
pub fn fair_ratios(run: &RunMetrics, baseline: &RunMetrics) -> Vec<(AgentId, f64)> {
    let base: HashMap<AgentId, f64> = baseline.jcts().into_iter().collect();
    run.jcts()
        .into_iter()
        .filter_map(|(a, j)| base.get(&a).map(|&b| (a, j / b.max(1e-12))))
        .collect()
}

/// Summary row for a fair-ratio distribution: fraction of agents with
/// ratio ≤ 1 (not delayed) and the worst-case delay in percent.
pub struct FairnessSummary {
    /// Fraction of agents with ratio ≤ 1.
    pub frac_not_delayed: f64,
    /// Worst delay over the baseline (%).
    pub worst_delay_pct: f64,
    /// Mean delay among delayed agents (%).
    pub avg_delay_pct_of_delayed: f64,
}

/// Summarize a fair-ratio distribution (Fig. 8 table).
pub fn fairness_summary(ratios: &[(AgentId, f64)]) -> FairnessSummary {
    if ratios.is_empty() {
        return FairnessSummary { frac_not_delayed: 1.0, worst_delay_pct: 0.0, avg_delay_pct_of_delayed: 0.0 };
    }
    let eps = 1e-9;
    let not_delayed = ratios.iter().filter(|(_, r)| *r <= 1.0 + eps).count();
    let worst = ratios.iter().map(|(_, r)| *r).fold(0.0f64, f64::max);
    let delayed: Vec<f64> = ratios.iter().map(|(_, r)| *r).filter(|r| *r > 1.0 + eps).collect();
    FairnessSummary {
        frac_not_delayed: not_delayed as f64 / ratios.len() as f64,
        worst_delay_pct: ((worst - 1.0).max(0.0)) * 100.0,
        avg_delay_pct_of_delayed: if delayed.is_empty() {
            0.0
        } else {
            (stats::mean(&delayed) - 1.0) * 100.0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tid(a: u32, i: u32) -> TaskId {
        TaskId { agent: a, index: i }
    }

    #[test]
    fn jct_accounting() {
        let mut m = RunMetrics::new();
        m.on_agent_arrival(1, 0.0);
        m.on_agent_arrival(2, 1.0);
        m.on_agent_complete(1, 5.0);
        m.on_agent_complete(2, 11.0);
        assert_eq!(m.jct(1), Some(5.0));
        assert_eq!(m.jct(2), Some(10.0));
        assert_eq!(m.completed_agents(), 2);
        assert!((m.avg_jct() - 7.5).abs() < 1e-12);
        assert!((m.p90_jct() - 9.5).abs() < 1e-9);
    }

    #[test]
    fn incomplete_agents_excluded() {
        let mut m = RunMetrics::new();
        m.on_agent_arrival(1, 0.0);
        m.on_agent_arrival(2, 0.0);
        m.on_agent_complete(1, 4.0);
        assert_eq!(m.jcts().len(), 1);
        assert_eq!(m.jct(2), None);
    }

    #[test]
    fn task_times() {
        let mut m = RunMetrics::new();
        m.on_task_admitted(tid(1, 0), 2.0);
        m.on_task_complete(tid(1, 0), 7.0);
        assert_eq!(m.task_admit_time(tid(1, 0)), Some(2.0));
        assert_eq!(m.task_complete_time(tid(1, 0)), Some(7.0));
    }

    #[test]
    fn fair_ratios_and_summary() {
        let mut run = RunMetrics::new();
        let mut base = RunMetrics::new();
        for (a, rj, bj) in [(1u32, 5.0, 10.0), (2, 10.0, 10.0), (3, 12.6, 10.0)] {
            run.on_agent_arrival(a, 0.0);
            run.on_agent_complete(a, rj);
            base.on_agent_arrival(a, 0.0);
            base.on_agent_complete(a, bj);
        }
        let ratios = fair_ratios(&run, &base);
        assert_eq!(ratios.len(), 3);
        let s = fairness_summary(&ratios);
        assert!((s.frac_not_delayed - 2.0 / 3.0).abs() < 1e-12);
        assert!((s.worst_delay_pct - 26.0).abs() < 1e-9);
        assert!((s.avg_delay_pct_of_delayed - 26.0).abs() < 1e-9);
    }

    #[test]
    fn merge_combines_disjoint_runs() {
        let mut a = RunMetrics::new();
        a.on_agent_arrival(0, 0.0);
        a.on_agent_complete(0, 4.0);
        a.on_task_admitted(tid(0, 0), 1.0);
        a.on_task_complete(tid(0, 0), 4.0);
        a.on_iteration(4.0, 4.0, 1, 0, 120);
        a.record_sched_decision(Duration::from_micros(100));

        let mut b = RunMetrics::new();
        b.on_agent_arrival(1, 0.0);
        b.on_agent_complete(1, 10.0);
        b.on_iteration(10.0, 10.0, 0, 2, 80);
        b.on_swap_out(tid(1, 0), 5.0);
        b.record_sched_decision(Duration::from_micros(300));

        a.merge(&b);
        assert_eq!(a.completed_agents(), 2);
        assert_eq!(a.jct(0), Some(4.0));
        assert_eq!(a.jct(1), Some(10.0));
        assert_eq!(a.iterations(), 2);
        assert_eq!(a.swap_out_count(), 1);
        assert_eq!(a.prefill_tokens_executed(), 200);
        assert_eq!(a.engine_time(), 10.0); // max, not sum (cluster makespan)
        assert_eq!(a.sched_decisions(), 2);
        assert!((a.sched_latency_ms() - 0.2).abs() < 1e-9);
        assert!((a.avg_jct() - 7.0).abs() < 1e-12);
        assert!((a.p99_jct() - a.percentile_jct(99.0)).abs() < 1e-12);
    }

    #[test]
    fn prefix_counters_and_hit_rate() {
        let mut m = RunMetrics::new();
        assert_eq!(m.prefix_hit_rate(), 0.0);
        m.on_prefix_lookup(0); // miss
        m.on_prefix_lookup(128); // hit
        m.on_prefix_lookup(64); // hit
        m.on_cache_occupancy(5);
        m.on_cache_occupancy(3);
        assert_eq!(m.prefix_lookups(), 3);
        assert_eq!(m.prefix_hits(), 2);
        assert!((m.prefix_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.prefill_tokens_saved(), 192);
        assert_eq!(m.cache_pages_peak(), 5);
    }

    #[test]
    fn prefix_counters_merge_sums_and_peaks() {
        let mut a = RunMetrics::new();
        a.on_prefix_lookup(100);
        a.on_prefix_lookup(0);
        a.on_cache_occupancy(7);
        a.on_iteration(1.0, 1.0, 1, 0, 50);

        let mut b = RunMetrics::new();
        b.on_prefix_lookup(30);
        b.on_cache_occupancy(4);
        b.on_iteration(2.0, 1.0, 1, 0, 70);

        a.merge(&b);
        assert_eq!(a.prefix_lookups(), 3);
        assert_eq!(a.prefix_hits(), 2);
        assert_eq!(a.prefill_tokens_saved(), 130);
        assert_eq!(a.prefill_tokens_executed(), 120);
        assert_eq!(a.cache_pages_peak(), 7, "gauge must max, not add");
    }

    #[test]
    fn spawn_and_correction_counters() {
        let mut m = RunMetrics::new();
        assert_eq!(m.spawned_tasks(), 0);
        assert_eq!(m.correction_samples(), 0);
        assert_eq!(m.correction_error_mean(), 0.0);
        m.on_task_spawned();
        m.on_task_spawned();
        m.on_cost_correction(1.0, 0.5);
        m.on_cost_correction(2.0, 0.1);
        assert_eq!(m.spawned_tasks(), 2);
        assert_eq!(m.correction_samples(), 2);
        assert!((m.correction_error_mean() - 0.3).abs() < 1e-12);
        assert_eq!(m.correction_trace(), &[(1.0, 0.5), (2.0, 0.1)]);

        let mut other = RunMetrics::new();
        other.on_task_spawned();
        other.on_cost_correction(1.5, 0.3);
        m.merge(&other);
        assert_eq!(m.spawned_tasks(), 3);
        assert_eq!(m.correction_samples(), 3);
        assert!((m.correction_error_mean() - 0.3).abs() < 1e-12);
        // Trace is merged in time order.
        assert_eq!(m.correction_trace(), &[(1.0, 0.5), (1.5, 0.3), (2.0, 0.1)]);
    }

    #[test]
    fn latency_hist_percentiles_and_merge() {
        let mut h = LatencyHist::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0.0);
        // 90 fast samples, 10 slow: p50 near 1 ms, p99 within bucket
        // resolution (~10%) of 100 ms (nearest-rank lands in the slow tail).
        h.record(1e-3, 90);
        h.record(0.1, 10);
        assert_eq!(h.count(), 100);
        let p50 = h.percentile(50.0);
        assert!((p50 / 1e-3 - 1.0).abs() < 0.11, "p50 {p50}");
        let p99 = h.percentile(99.0);
        assert!((p99 / 0.1 - 1.0).abs() < 0.11, "p99 {p99}");
        assert!((h.mean() - (90.0 * 1e-3 + 10.0 * 0.1) / 100.0).abs() < 1e-12);
        // Merge is bucket-exact.
        let mut other = LatencyHist::default();
        other.record(0.1, 100);
        h.merge(&other);
        assert_eq!(h.count(), 200);
        let p50 = h.percentile(50.0);
        assert!((p50 / 0.1 - 1.0).abs() < 0.11, "merged p50 {p50}");
        // Out-of-range values clamp instead of panicking.
        let mut h = LatencyHist::default();
        h.record(0.0, 1);
        h.record(1e9, 1);
        assert_eq!(h.count(), 2);
        assert!(h.percentile(100.0) > h.percentile(1.0));
    }

    #[test]
    fn latency_hist_edge_cases() {
        // Empty histogram: every statistic is a well-defined zero, at any q.
        let empty = LatencyHist::default();
        assert_eq!(empty.count(), 0);
        assert_eq!(empty.mean(), 0.0);
        for q in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(empty.percentile(q), 0.0, "empty hist q={q}");
        }
        // Merging an empty histogram changes nothing; merging INTO an empty
        // one reproduces the source exactly (buckets are copied, not
        // re-quantized).
        let mut h = LatencyHist::default();
        h.record(2e-3, 5);
        h.record(0.5, 1);
        let snapshot = (h.count(), h.mean(), h.percentile(50.0), h.percentile(99.0));
        h.merge(&LatencyHist::default());
        assert_eq!((h.count(), h.mean(), h.percentile(50.0), h.percentile(99.0)), snapshot);
        let mut fresh = LatencyHist::default();
        fresh.merge(&h);
        assert_eq!(
            (fresh.count(), fresh.mean(), fresh.percentile(50.0), fresh.percentile(99.0)),
            snapshot
        );

        // Single sample: every percentile (incl. the q=0 and q=100 extremes)
        // lands in its bucket, within the ~10% bucket resolution.
        let mut one = LatencyHist::default();
        one.record(3e-3, 1);
        assert_eq!(one.count(), 1);
        assert_eq!(one.mean(), 3e-3);
        for q in [0.0, 50.0, 100.0] {
            let p = one.percentile(q);
            assert!((p / 3e-3 - 1.0).abs() < 0.11, "single-sample q={q} -> {p}");
        }

        // q=0 and q=100 bracket the distribution: q=0 clamps to rank 1 (the
        // smallest sample), q=100 reaches the largest.
        let mut two = LatencyHist::default();
        two.record(1e-3, 10);
        two.record(0.1, 10);
        let (p0, p100) = (two.percentile(0.0), two.percentile(100.0));
        assert!((p0 / 1e-3 - 1.0).abs() < 0.11, "q=0 -> {p0}");
        assert!((p100 / 0.1 - 1.0).abs() < 0.11, "q=100 -> {p100}");
        assert!(two.percentile(50.0) <= p100 && p0 <= two.percentile(50.0));

        // Merge-then-percentile == record-everything-then-percentile: the
        // merge is bucket-exact, so the two orders cannot disagree.
        let samples_a = [(1e-4, 7u64), (2e-3, 3), (0.05, 2)];
        let samples_b = [(5e-4, 4u64), (0.01, 6), (1.5, 1)];
        let mut merged = LatencyHist::default();
        let mut direct = LatencyHist::default();
        let mut a = LatencyHist::default();
        let mut b = LatencyHist::default();
        for &(x, w) in &samples_a {
            a.record(x, w);
            direct.record(x, w);
        }
        for &(x, w) in &samples_b {
            b.record(x, w);
            direct.record(x, w);
        }
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.count(), direct.count());
        // Counts/buckets are integer-exact; the mean's running sum may
        // associate differently, so compare within float tolerance.
        assert!((merged.mean() - direct.mean()).abs() < 1e-12);
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(merged.percentile(q), direct.percentile(q), "q={q} diverged");
        }
        // Zero-weight records are dropped entirely.
        let before = merged.count();
        merged.record(1.0, 0);
        assert_eq!(merged.count(), before);
    }

    #[test]
    fn latency_hist_merge_is_associative_and_commutative() {
        // ISSUE 6: parallel replica simulation merges per-replica histograms
        // in replica index order, and the determinism guarantee leans on the
        // merge being order-insensitive. Buckets and totals are u64 sums —
        // exactly associative AND commutative — so every permutation and
        // every grouping of the same histograms must agree bit for bit on
        // counts and percentiles. The samples here are dyadic rationals
        // (exact in binary), so even the f64 running sum (and therefore the
        // mean) is bit-identical across orders.
        let parts: Vec<LatencyHist> = [
            vec![(0.25, 7u64), (0.5, 3)],
            vec![(0.125, 4), (8.0, 2)],
            vec![(0.0625, 1), (0.25, 9), (2.0, 5)],
            vec![(16.0, 6)],
        ]
        .into_iter()
        .map(|samples| {
            let mut h = LatencyHist::default();
            for (x, w) in samples {
                h.record(x, w);
            }
            h
        })
        .collect();

        let fold = |order: &[usize]| {
            let mut acc = LatencyHist::default();
            for &i in order {
                acc.merge(&parts[i]);
            }
            acc
        };
        let fingerprint = |h: &LatencyHist| {
            (h.counts, h.total, h.sum.to_bits())
        };

        let want = fingerprint(&fold(&[0, 1, 2, 3]));
        // Commutativity: every permutation of the four parts.
        let mut perms: Vec<Vec<usize>> = Vec::new();
        for a in 0..4usize {
            for b in 0..4 {
                for c in 0..4 {
                    for d in 0..4 {
                        let p = vec![a, b, c, d];
                        let mut s = p.clone();
                        s.sort_unstable();
                        if s == [0, 1, 2, 3] {
                            perms.push(p);
                        }
                    }
                }
            }
        }
        assert_eq!(perms.len(), 24);
        for p in &perms {
            let got = fold(p);
            assert_eq!(fingerprint(&got), want, "permutation {p:?} diverged");
            for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
                assert_eq!(
                    got.percentile(q).to_bits(),
                    fold(&[0, 1, 2, 3]).percentile(q).to_bits(),
                    "q={q} diverged under permutation {p:?}"
                );
            }
        }
        // Associativity: (a⊕b)⊕(c⊕d) equals ((a⊕b)⊕c)⊕d.
        let mut left = LatencyHist::default();
        left.merge(&parts[0]);
        left.merge(&parts[1]);
        let mut right = LatencyHist::default();
        right.merge(&parts[2]);
        right.merge(&parts[3]);
        let mut grouped = LatencyHist::default();
        grouped.merge(&left);
        grouped.merge(&right);
        assert_eq!(fingerprint(&grouped), want, "re-grouped merge diverged");
    }

    #[test]
    fn recompute_counters_and_merge() {
        let mut m = RunMetrics::new();
        assert_eq!(m.recompute_count(), 0);
        assert_eq!(m.recomputed_tokens(), 0);
        m.on_recompute_drop(tid(1, 0), 1.0, 120);
        m.on_recompute_drop(tid(2, 0), 2.0, 30);
        assert_eq!(m.recompute_count(), 2);
        assert_eq!(m.recomputed_tokens(), 150);
        let mut other = RunMetrics::new();
        other.on_recompute_drop(tid(3, 0), 0.5, 50);
        m.merge(&other);
        assert_eq!(m.recompute_count(), 3);
        assert_eq!(m.recomputed_tokens(), 200);
    }

    #[test]
    fn decode_itl_and_prefill_stalls_flow_through_metrics() {
        let mut m = RunMetrics::new();
        assert_eq!(m.decode_itl_samples(), 0);
        assert_eq!(m.prefill_stalls(), 0);
        // 3 decoders at 50 ms, then 1 decoder at 200 ms.
        m.on_iteration(0.05, 0.05, 1, 3, 100);
        m.on_iteration(0.25, 0.2, 0, 1, 0);
        m.on_prefill_stalls(2);
        assert_eq!(m.decode_itl_samples(), 4);
        assert!((m.decode_itl_percentile(99.0) / 0.2 - 1.0).abs() < 0.11);
        assert!((m.decode_itl_mean() - (3.0 * 0.05 + 0.2) / 4.0).abs() < 1e-12);
        assert_eq!(m.prefill_stalls(), 2);
        // Merge adds counters and folds histograms.
        let mut other = RunMetrics::new();
        other.on_iteration(1.0, 0.4, 0, 2, 0);
        other.on_prefill_stalls(1);
        m.merge(&other);
        assert_eq!(m.decode_itl_samples(), 6);
        assert_eq!(m.prefill_stalls(), 3);
        assert!((m.decode_itl_percentile(99.0) / 0.4 - 1.0).abs() < 0.11);
    }

    #[test]
    fn ttft_hist_anchors_at_task_ready_and_merges() {
        let mut m = RunMetrics::new();
        assert_eq!(m.ttft_samples(), 0);
        assert_eq!(m.ttft_mean(), 0.0);
        // Ready at 1.0, admitted at 3.0, first token at 5.0: TTFT = 4.0 —
        // queueing delay (ready → admit) is included.
        m.on_task_ready(tid(1, 0), 1.0);
        m.on_task_admitted(tid(1, 0), 3.0);
        m.on_first_token(tid(1, 0), 5.0);
        assert_eq!(m.ttft_samples(), 1);
        assert!((m.ttft_mean() - 4.0).abs() < 1e-12);
        // A first token without a recorded ready time is ignored, not a
        // panic (defensive: replica merges may see partial maps).
        m.on_first_token(tid(9, 0), 2.0);
        assert_eq!(m.ttft_samples(), 1);
        // Merge is bucket-exact, like decode ITL.
        let mut other = RunMetrics::new();
        other.on_task_ready(tid(2, 0), 0.0);
        other.on_first_token(tid(2, 0), 2.0);
        m.merge(&other);
        assert_eq!(m.ttft_samples(), 2);
        assert!((m.ttft_mean() - 3.0).abs() < 1e-12);
        assert!(m.ttft_percentile(99.0) >= m.ttft_percentile(10.0));
    }

    #[test]
    fn deadline_counters_index_by_class_and_merge() {
        let mut m = RunMetrics::new();
        assert_eq!(m.deadline_miss_rate(), 0.0);
        assert!(m.class_deadlines().is_empty());
        // 2 TTFT verdicts (1 miss) for a small class, 8 ITL verdicts
        // (2 misses) for a large one.
        m.on_ttft_deadline(AgentClass::EquationVerification, true);
        m.on_ttft_deadline(AgentClass::EquationVerification, false);
        m.on_itl_deadlines(AgentClass::DocumentMerging, 8, 2);
        assert!((m.deadline_miss_rate() - 3.0 / 10.0).abs() < 1e-12);
        let per = m.class_deadlines();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].0, AgentClass::EquationVerification);
        assert_eq!((per[0].1.ttft_total, per[0].1.ttft_miss), (2, 1));
        assert_eq!(per[1].0, AgentClass::DocumentMerging);
        assert!((per[1].1.miss_rate() - 0.25).abs() < 1e-12);
        // Merge adds elementwise per class (cluster totals).
        let mut other = RunMetrics::new();
        other.on_ttft_deadline(AgentClass::EquationVerification, true);
        other.on_itl_deadlines(AgentClass::SelfConsistency, 4, 4);
        m.merge(&other);
        assert!((m.deadline_miss_rate() - 8.0 / 15.0).abs() < 1e-12);
        let per = m.class_deadlines();
        assert_eq!(per.len(), 3);
        assert_eq!((per[0].1.ttft_total, per[0].1.ttft_miss), (3, 2));
    }

    #[test]
    fn sched_latency_stats() {
        let mut m = RunMetrics::new();
        m.record_sched_decision(Duration::from_micros(100));
        m.record_sched_decision(Duration::from_micros(300));
        assert!((m.sched_latency_ms() - 0.2).abs() < 1e-9);
        assert!((m.sched_latency_max_ms() - 0.3).abs() < 1e-9);
        assert_eq!(m.sched_decisions(), 2);
    }
}
