//! Property test (ISSUE 2 satellite): `BlockAllocator` conservation under
//! randomized alloc / extend / share / cow / swap / free sequences.
//!
//! Invariants after every operation (via `check_invariants_shared`):
//!   * conservation — free pages + in-use pages == total pages (swapped
//!     sequences hold no device pages, so their slots sit in `free`);
//!   * every non-free page's refcount ≥ 1 and exactly equal to the number
//!     of block tables holding it;
//!   * token accounting — `device_tokens` / `swapped_tokens` match the sum
//!     over sequences.
//! After releasing every live sequence the pool must be fully free again
//! (no leaked pages, shared or otherwise).

use justitia::kv::{BlockAllocator, KvResidence, PageId};
use justitia::util::prop::{check, Config, U64Range, VecOf};
use justitia::workload::TaskId;

const PAGES: u32 = 12;
const PAGE_SIZE: u32 = 4;

fn tid(i: u32) -> TaskId {
    TaskId { agent: 0, index: i }
}

fn pick(v: &[u32], sel: usize) -> Option<u32> {
    if v.is_empty() {
        None
    } else {
        Some(v[sel % v.len()])
    }
}

/// Interpret one op-code sequence against a small allocator. Invalid ops
/// (no live sequence, wrong residence) are skipped; fallible ops are allowed
/// to fail with `OutOfPages` — what must never happen is an invariant break.
fn run_ops(ops: &[u64]) -> Result<(), String> {
    let mut kv = BlockAllocator::new(PAGES, PAGE_SIZE);
    let mut next_id: u32 = 0;
    let mut live: Vec<u32> = Vec::new();
    for (step, &op) in ops.iter().enumerate() {
        let kind = op % 7;
        let sel = (op / 7) as usize;
        match kind {
            // Allocate a fresh sequence with a 0..19-token prompt.
            0 => {
                let prompt = (op / 49 % 20) as u32;
                let id = next_id;
                next_id += 1;
                if kv.allocate(tid(id), prompt).is_ok() {
                    live.push(id);
                }
            }
            // Append one decode token (may allocate / copy-on-write).
            1 => {
                if let Some(s) = pick(&live, sel) {
                    let _ = kv.append_token(tid(s));
                }
            }
            // Release.
            2 => {
                if let Some(s) = pick(&live, sel) {
                    kv.release(tid(s)).map_err(|e| format!("step {step}: release: {e}"))?;
                    live.retain(|&x| x != s);
                }
            }
            // Swap out.
            3 => {
                if let Some(s) = pick(&live, sel) {
                    if kv.residence(tid(s)) == Some(KvResidence::Device) {
                        kv.swap_out(tid(s)).map_err(|e| format!("step {step}: swap_out: {e}"))?;
                    }
                }
            }
            // Swap in.
            4 => {
                if let Some(s) = pick(&live, sel) {
                    if kv.can_swap_in(tid(s)) {
                        kv.swap_in(tid(s)).map_err(|e| format!("step {step}: swap_in: {e}"))?;
                    }
                }
            }
            // Share a donor's full prompt pages into a new sequence.
            5 => {
                if let Some(donor) = pick(&live, sel) {
                    if kv.residence(tid(donor)) == Some(KvResidence::Device) {
                        let tokens = kv.seq_tokens(tid(donor)).unwrap();
                        let full = (tokens / PAGE_SIZE) as usize;
                        let shared: Vec<PageId> =
                            kv.block_table(tid(donor)).unwrap()[..full].to_vec();
                        let id = next_id;
                        next_id += 1;
                        if kv.share_prefix(tid(id), &shared, tokens).is_ok() {
                            live.push(id);
                        }
                    }
                }
            }
            // Copy-on-write split of an arbitrary table page.
            6 => {
                if let Some(s) = pick(&live, sel) {
                    if kv.residence(tid(s)) == Some(KvResidence::Device) {
                        let n = kv.block_table(tid(s)).unwrap().len();
                        let _ = kv.cow_split(tid(s), sel % n.max(1));
                    }
                }
            }
            _ => unreachable!(),
        }
        kv.check_invariants().map_err(|e| format!("step {step} (op {op}): {e}"))?;
    }
    // Drain: releasing everything must return the pool to fully free.
    for s in live {
        kv.release(tid(s)).map_err(|e| format!("drain: {e}"))?;
    }
    if kv.free_pages() != PAGES {
        return Err(format!("leaked pages: {} free of {PAGES} after drain", kv.free_pages()));
    }
    kv.check_invariants().map_err(|e| format!("after drain: {e}"))
}

#[test]
fn kv_conservation_under_random_op_sequences() {
    let cfg = Config { cases: 250, seed: 0x5eed_b10c, max_shrink_steps: 400 };
    let strat = VecOf { inner: U64Range { lo: 0, hi: 1 << 40 }, min_len: 0, max_len: 120 };
    check(&cfg, &strat, |ops| run_ops(ops));
}

#[test]
fn kv_allocation_trace_is_release_order_independent() {
    // The same logical history with two different release interleavings must
    // hand out identical pages afterwards (deterministic min-heap free list).
    let trace = |first: u32, second: u32| {
        let mut kv = BlockAllocator::new(10, 4);
        for i in 0..4 {
            kv.allocate(tid(i), 8).unwrap();
        }
        kv.release(tid(first)).unwrap();
        kv.release(tid(second)).unwrap();
        kv.allocate(tid(10), 16).unwrap();
        kv.block_table(tid(10)).unwrap().to_vec()
    };
    assert_eq!(trace(1, 2), trace(2, 1));
    assert_eq!(trace(0, 3), trace(3, 0));
}
