//! Minimal JSON parser/serializer (serde is unavailable on this image).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough for
//! config files, experiment result dumps, and the model-config artifact
//! emitted by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in sorted order (BTreeMap) so that
/// serialized output is deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as `f64`).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (keys kept sorted).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with its byte position.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// Human-readable cause.
    pub msg: String,
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    /// Number value, if `self` is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer value, if exactly representable.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| if x >= 0.0 && x.fract() == 0.0 { Some(x as u64) } else { None })
    }

    /// Integer value, if exactly representable.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| if x.fract() == 0.0 { Some(x as i64) } else { None })
    }

    /// String value, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool value, if a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Key → value map, if an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

// Convenience constructors used throughout the experiment/metrics code.
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i32> for Json {
    fn from(x: i32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from `(key, value)` pairs.
pub fn obj<const N: usize>(pairs: [(&str, Json); N]) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("missing low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?);
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-decode multibyte UTF-8 from the source.
                    let start = self.pos - 1;
                    let width = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.5e-1}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x\ny"));
        assert!(v.get("a").as_arr().unwrap()[2].get("c") == &Json::Null);
        assert!((v.get("d").as_f64().unwrap() + 0.25).abs() < 1e-12);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        // And raw multibyte passes through.
        let v = Json::parse("\"héllo 😀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo 😀"));
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\"}", "nul", "01a", "\"abc", "[1 2]", "{} {}"] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn deterministic_object_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        assert_eq!(v.dump(), r#"{"a":2,"m":3,"z":1}"#);
    }

    #[test]
    fn escaping_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn obj_builder_and_get() {
        let v = obj([("x", 1u64.into()), ("y", "hi".into())]);
        assert_eq!(v.get("x").as_u64(), Some(1));
        assert_eq!(v.get("y").as_str(), Some("hi"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.5).dump(), "3.5");
    }
}
