//! Engine hot-path macro-bench (ISSUE 6): events/sec of the event-driven
//! core at 10k and 100k agents, with a regression gate against a committed
//! baseline.
//!
//! One timed end-to-end `run_suite` per size (suite-scale runs are too long
//! for iterated sampling); "events/sec" is retired engine iterations per
//! wall second — the discrete-event analogue of a tick rate. The JSON
//! artifact lands at `results/BENCH_engine.json`; CI uploads it and fails
//! the job when a measured rate drops more than `tolerance` (default 15%)
//! below the committed baseline `ci/bench_engine_baseline.json` (pointed at
//! via `JUSTITIA_BENCH_BASELINE`; without the env var the gate is skipped so
//! local runs never fail on slow laptops). Baseline numbers are deliberately
//! conservative floors — ratchet them upward as real runner numbers accrue.
//!
//! ISSUE 7 adds a traced row: the same 10k event-core run with the flight
//! recorder ON at the default sample stride, reported as an overhead
//! percentage against the untraced rate. The regression gate stays on the
//! untraced rows; a separate `trace_overhead_pct_max` key in the baseline
//! (default 5%) bounds the recorder's cost when the gate is armed.

use justitia::config::{Config, Policy, WorkloadConfig};
use justitia::cost::CostModel;
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::util::bench::section;
use justitia::util::json::{obj, Json};
use std::time::Instant;

struct Row {
    agents: usize,
    iterations: u64,
    wall_s: f64,
    events_per_sec: f64,
}

fn run_once(n_agents: usize, event_core: bool, trace: bool) -> Row {
    let mut cfg = Config::default();
    cfg.event_core = event_core;
    // Default trace_sample / trace_cap — exactly what `--trace` ships.
    cfg.trace = trace;
    cfg.workload =
        WorkloadConfig { n_agents, seed: 42, ..Default::default() }.with_density(3.0);
    // Lean suite: input text is predictor-only and dominates memory at scale.
    let suite = justitia::workload::trace::build_suite_lean(&cfg.workload);
    let sched = justitia::sched::build(Policy::Justitia, cfg.backend.kv_tokens, 1.0);
    let mut engine = Engine::new(&cfg, sched, SimBackend::new(&cfg.backend));
    let model = CostModel::MemoryCentric;
    let t0 = Instant::now();
    let makespan = engine.run_suite(&suite, |a| model.agent_cost(a));
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    let iterations = engine.metrics.iterations();
    assert_eq!(
        engine.metrics.completed_agents(),
        n_agents,
        "bench run dropped agents (makespan {makespan:.1}s)"
    );
    Row { agents: n_agents, iterations, wall_s, events_per_sec: iterations as f64 / wall_s }
}

fn main() {
    section("engine hot path (event core)");
    let mut rows = Vec::new();
    for n in [10_000usize, 100_000] {
        let r = run_once(n, true, false);
        println!(
            "event-core {:>7} agents: {:>9} iterations in {:>7.2}s = {:>10.0} events/sec",
            r.agents, r.iterations, r.wall_s, r.events_per_sec
        );
        rows.push(r);
    }

    // The legacy tick loop at the small size, for the speedup column.
    let tick = run_once(10_000, false, false);
    println!(
        "tick-loop  {:>7} agents: {:>9} iterations in {:>7.2}s = {:>10.0} events/sec",
        tick.agents, tick.iterations, tick.wall_s, tick.events_per_sec
    );
    let speedup = rows[0].events_per_sec / tick.events_per_sec.max(1e-9);
    println!("event core vs tick loop at 10k agents: {speedup:.2}x");

    // Flight recorder overhead at the default sample stride (ISSUE 7): same
    // 10k event-core run with `--trace` on. Must stay under ~5%.
    let traced = run_once(10_000, true, true);
    println!(
        "traced     {:>7} agents: {:>9} iterations in {:>7.2}s = {:>10.0} events/sec",
        traced.agents, traced.iterations, traced.wall_s, traced.events_per_sec
    );
    let trace_overhead_pct =
        (1.0 - traced.events_per_sec / rows[0].events_per_sec.max(1e-9)) * 100.0;
    println!("flight recorder overhead at 10k agents: {trace_overhead_pct:.1}%");

    let json = obj([
        ("bench", Json::Str("engine_hot_path".into())),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        obj([
                            ("agents", Json::Num(r.agents as f64)),
                            ("iterations", Json::Num(r.iterations as f64)),
                            ("wall_s", Json::Num(r.wall_s)),
                            ("events_per_sec", Json::Num(r.events_per_sec)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("tick_10k_events_per_sec", Json::Num(tick.events_per_sec)),
        ("event_vs_tick_speedup_10k", Json::Num(speedup)),
        ("traced_10k_events_per_sec", Json::Num(traced.events_per_sec)),
        ("trace_overhead_pct", Json::Num(trace_overhead_pct)),
    ]);
    let _ = std::fs::create_dir_all("results");
    let path = std::path::Path::new("results/BENCH_engine.json");
    if let Err(e) = std::fs::write(path, json.pretty() + "\n") {
        eprintln!("warn: failed writing {}: {e}", path.display());
    } else {
        println!("wrote {}", path.display());
    }

    // Regression gate (CI only: JUSTITIA_BENCH_BASELINE points at the
    // committed baseline; absent locally, the gate is informational).
    let Some(baseline_path) = std::env::var_os("JUSTITIA_BENCH_BASELINE") else {
        println!("JUSTITIA_BENCH_BASELINE unset; skipping the regression gate");
        return;
    };
    let text = std::fs::read_to_string(&baseline_path)
        .unwrap_or_else(|e| panic!("read baseline {baseline_path:?}: {e}"));
    let base = Json::parse(&text).expect("baseline JSON");
    let tolerance = base.get("tolerance").as_f64().unwrap_or(0.15);
    let mut failed = false;
    for r in &rows {
        let key = r.agents.to_string();
        let Some(floor) = base.get("events_per_sec").get(&key).as_f64() else {
            println!("baseline has no floor for {key} agents; skipping");
            continue;
        };
        let min_ok = floor * (1.0 - tolerance);
        if r.events_per_sec < min_ok {
            eprintln!(
                "REGRESSION: {} agents at {:.0} events/sec, more than {:.0}% below \
                 the baseline {:.0} (floor {:.0})",
                r.agents,
                r.events_per_sec,
                tolerance * 100.0,
                floor,
                min_ok
            );
            failed = true;
        } else {
            println!(
                "gate ok: {} agents at {:.0} events/sec >= {:.0} (baseline {:.0} - {:.0}%)",
                r.agents,
                r.events_per_sec,
                min_ok,
                floor,
                tolerance * 100.0
            );
        }
    }
    // Recorder overhead gate: untraced vs traced back-to-back in the same
    // process, so runner noise largely cancels.
    let overhead_max = base.get("trace_overhead_pct_max").as_f64().unwrap_or(5.0);
    if trace_overhead_pct > overhead_max {
        eprintln!(
            "REGRESSION: flight recorder overhead {trace_overhead_pct:.1}% exceeds \
             the {overhead_max:.1}% budget at the default sample stride"
        );
        failed = true;
    } else {
        println!("gate ok: trace overhead {trace_overhead_pct:.1}% <= {overhead_max:.1}%");
    }
    if failed {
        std::process::exit(1);
    }
}
