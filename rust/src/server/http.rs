//! Minimal HTTP/1.1 front-end over the PJRT serving engine.
//!
//! Endpoints:
//!   `POST /agents`   — submit an agent: `{"class": "DM", "stages": [[{"p":..,"d":..}]]}`
//!                      (stages optional: omitted → generated from the class
//!                      template with a fresh seed). Returns the agent id.
//!   `GET  /agents/N` — status + JCT when complete.
//!   `GET  /metrics`  — aggregate serving metrics, Prometheus text format.
//!   `GET  /trace`    — the merged Chrome/Perfetto trace dump (404 unless
//!                      the server was started with `--trace`).
//!   `GET  /healthz`  — liveness.
//!
//! Architecture: acceptor threads parse requests and push submissions over a
//! channel; a single engine thread owns a [`ClusterDispatcher`] over one or
//! more `Engine<PjrtBackend>` replicas and steps it whenever work exists
//! (Python never on this path — the model is the AOT-compiled PJRT
//! executable). With `--replicas 1` (the default) the dispatcher degenerates
//! to the single-engine path.

use crate::cluster::{ClusterDispatcher, Placement};
use crate::config::{BackendProfile, Config, Policy};
use crate::cost::CostModel;
use crate::engine::Engine;
use crate::runtime::{PjrtBackend, PjrtModel};
use crate::util::json::{obj, Json};
use crate::workload::{AgentClass, AgentSpec, InferenceSpec, TaskId};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// A parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    /// HTTP method.
    pub method: String,
    /// Request path.
    pub path: String,
    /// Raw request body.
    pub body: Vec<u8>,
}

/// Parse one HTTP/1.1 request from a stream.
pub fn parse_request(stream: &mut dyn Read) -> Result<Request> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("method")?.to_string();
    let path = parts.next().context("path")?.to_string();

    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length.min(1 << 20)];
    if !body.is_empty() {
        reader.read_exact(&mut body)?;
    }
    Ok(Request { method, path, body })
}

/// Write an HTTP response with the given content type (the routing table
/// picks `application/json` for API routes and Prometheus' registered
/// `text/plain` flavor for `/metrics`).
pub fn write_response(
    stream: &mut dyn Write,
    status: u16,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

/// Shared serving state.
pub(crate) struct Shared {
    /// agent id → (class, submit wall time, Option<jct>).
    agents: Mutex<BTreeMap<u32, (String, std::time::Instant, Option<f64>)>>,
    next_id: AtomicU32,
    /// Trained per-class cost predictor (`--predict`): submissions are
    /// priced by the model (prompt text → Ĉ_j) instead of the ground-truth
    /// oracle, and the engines derive per-task tags from the same
    /// prediction — the predictor-in-the-loop serving path (ISSUE 5).
    predictor: Option<crate::predictor::PerClassPredictor>,
    /// Latest merged Chrome-trace dump, refreshed by the engine thread each
    /// time it goes idle (`None` until the first refresh, or forever when
    /// the server runs without `--trace`). Stored pre-serialized so the
    /// `/trace` handler never touches the engines.
    trace: Mutex<Option<String>>,
}

/// Parse an agent submission body into an AgentSpec.
pub fn parse_agent_submission(
    body: &str,
    id: u32,
    seed: u64,
) -> Result<AgentSpec> {
    let v = Json::parse(body).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let class_name = v.get("class").as_str().unwrap_or("EV");
    let class = AgentClass::by_short_name(class_name)
        .with_context(|| format!("unknown class '{class_name}'"))?;
    if let Some(stages_json) = v.get("stages").as_arr() {
        let mut stages = Vec::new();
        for st in stages_json {
            let mut tasks = Vec::new();
            for t in st.as_arr().context("stage must be an array")? {
                // Ids/stages/deps are stamped by from_stages below.
                tasks.push(InferenceSpec {
                    id: TaskId { agent: id, index: 0 },
                    stage: 0,
                    deps: Vec::new(),
                    prompt_tokens: t.get("p").as_u64().context("p")? as u32,
                    decode_tokens: t.get("d").as_u64().context("d")? as u32,
                    kind: "http",
                    prefix_group: None,
                });
            }
            stages.push(tasks);
        }
        anyhow::ensure!(!stages.is_empty() && stages.iter().all(|s| !s.is_empty()), "empty stages");
        Ok(AgentSpec::from_stages(
            id,
            class,
            0.0,
            stages,
            v.get("input").as_str().unwrap_or("").to_string(),
        ))
    } else {
        // Generate from the class template.
        let mut gen = crate::workload::generator::Generator::new(seed ^ id as u64);
        let mut a = gen.agent(class, id, 0.0);
        // HTTP-served model is the tiny artifact: clamp lengths to fit.
        for t in &mut a.tasks {
            t.prompt_tokens = t.prompt_tokens.clamp(1, 48) / 4 + 2;
            t.decode_tokens = t.decode_tokens.clamp(1, 48) / 4 + 2;
        }
        Ok(a)
    }
}

/// Run the HTTP server (blocks forever). `replicas` PJRT engines are stood
/// up behind a [`ClusterDispatcher`] using `placement`; with one replica the
/// dispatcher is a transparent pass-through. With `use_predictor` a
/// per-class cost predictor is trained at startup and submissions are
/// priced by it (the schedulers never see oracle costs). `trace` is the
/// `--trace` wiring: `Some((sample_stride, ring_cap))` turns every
/// replica's flight recorder on and publishes the merged Chrome dump at
/// `GET /trace`; `None` (the default) keeps the engines bit-identical to
/// an untraced run and `/trace` answers 404.
pub fn serve(
    artifacts: &std::path::Path,
    port: u16,
    policy: Policy,
    replicas: usize,
    placement: Placement,
    use_predictor: bool,
    trace: Option<(u32, usize)>,
) -> Result<()> {
    let predictor = if use_predictor {
        println!("training per-class cost predictor…");
        let (p, report) =
            crate::predictor::train_per_class(CostModel::MemoryCentric, 60, 10, 0x5eed);
        println!(
            "predictor: rel_error {:.1}%, infer {:.2} ms, trained in {:.1}s",
            report.rel_error * 100.0,
            report.infer_ms,
            report.train_secs
        );
        Some(p)
    } else {
        None
    };
    let shared = Arc::new(Shared {
        agents: Mutex::new(BTreeMap::new()),
        next_id: AtomicU32::new(0),
        predictor,
        trace: Mutex::new(None),
    });
    let (tx, rx) = mpsc::channel::<(AgentSpec, f64)>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<String>>();

    // Engine thread owns the PJRT models outright — the xla crate's handles
    // are not Send, so every replica's model is loaded *inside* the thread.
    {
        let shared = Arc::clone(&shared);
        let artifacts = artifacts.to_path_buf();
        std::thread::Builder::new().name("justitia-engine".into()).spawn(move || {
            let n = replicas.max(1);
            let mut engines = Vec::with_capacity(n);
            let mut kv_tokens = 0u64;
            let mut ready_msg = String::new();
            for i in 0..n {
                // One model (and one paged pool) per replica.
                let model = match PjrtModel::load(&artifacts) {
                    Ok(m) => m,
                    Err(e) => {
                        // Readiness is reported only after EVERY replica
                        // loads, so a failure on any replica (e.g. OOM on a
                        // later weight copy) reaches the caller.
                        let _ = ready_tx
                            .send(Err(e.context(format!("loading replica {i} of {n}"))));
                        return;
                    }
                };
                let m = &model.manifest;
                if i == 0 {
                    kv_tokens = (m.n_pages * m.page_size) as u64;
                    ready_msg = format!(
                        "loaded model from {} (platform {}, {} pages × {} tokens, {} replica{})",
                        artifacts.display(),
                        model.platform(),
                        m.n_pages,
                        m.page_size,
                        n,
                        if n == 1 { "" } else { "s" }
                    );
                }
                let mut cfg2 = Config::default();
                cfg2.backend = BackendProfile {
                    name: "tiny-cpu".into(),
                    kv_tokens: (m.n_pages * m.page_size) as u64,
                    page_size: m.page_size as u32,
                    alpha: 0.0,
                    beta_prefill: 0.0,
                    beta_decode: 0.0,
                    swap_cost_per_token: 0.0,
                    beta_mixed: 0.0,
                    host_kv_tokens: None,
                    swap_bw_tokens_per_sec: 0.0,
                };
                cfg2.max_batch = model.max_decode_batch();
                // Per-task scheduler tags derive from the submitted Ĉ_j in
                // predictor mode (see Engine::push_task).
                cfg2.use_predictor = use_predictor;
                if let Some((sample, cap)) = trace {
                    cfg2.trace = true;
                    cfg2.trace_sample = sample;
                    cfg2.trace_cap = cap;
                }
                let sched = crate::sched::build(policy, cfg2.backend.kv_tokens, 1.0);
                engines.push(Engine::new(&cfg2, sched, PjrtBackend::new(model)));
            }
            let _ = ready_tx.send(Ok(ready_msg));
            let mut cluster = ClusterDispatcher::new(engines, placement, kv_tokens, 1.0);
            loop {
                // Drain pending submissions.
                while let Ok((spec, cost)) = rx.try_recv() {
                    cluster.submit(spec, cost);
                }
                if cluster.has_work() {
                    cluster.step();
                    // Record completions.
                    let mut agents = shared.agents.lock().unwrap();
                    for (id, entry) in agents.iter_mut() {
                        if entry.2.is_none() && cluster.agent_complete_time(*id).is_some() {
                            entry.2 = Some(entry.1.elapsed().as_secs_f64());
                        }
                    }
                } else {
                    // Idle: publish a fresh trace dump (the only writer of
                    // `shared.trace`, so `/trace` serves a consistent
                    // snapshot), then block on the next submission.
                    if trace.is_some() {
                        if let Some(json) = cluster.merged_trace_chrome() {
                            *shared.trace.lock().unwrap() = Some(json.dump());
                        }
                    }
                    match rx.recv() {
                        Ok((spec, cost)) => {
                            cluster.submit(spec, cost);
                        }
                        Err(_) => break,
                    }
                }
            }
        })?;
    }
    println!("{}", ready_rx.recv().context("engine thread died")??);

    let listener = TcpListener::bind(("127.0.0.1", port))?;
    println!(
        "serving on http://127.0.0.1:{port} (policy {}, placement {})",
        policy.name(),
        placement.name()
    );
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let shared = Arc::clone(&shared);
        let tx = tx.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, &shared, &tx);
        });
    }
    Ok(())
}

fn handle_conn(
    mut stream: TcpStream,
    shared: &Shared,
    tx: &mpsc::Sender<(AgentSpec, f64)>,
) -> Result<()> {
    let req = parse_request(&mut stream)?;
    let (status, content_type, body) = route(&req, shared, tx);
    write_response(&mut stream, status, content_type, &body)?;
    Ok(())
}

/// The Prometheus text-format content type (the exposition format spec's
/// registered flavor — scrapers key on the `version` parameter).
pub const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

const JSON_CONTENT_TYPE: &str = "application/json";

/// One Prometheus metric: `# HELP` + `# TYPE` + the sample line.
fn prom_metric(out: &mut String, name: &str, kind: &str, help: &str, value: f64) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    // NaN (empty-percentile) serializes as Prometheus' literal NaN.
    if value.is_nan() {
        let _ = writeln!(out, "{name} NaN");
    } else {
        let _ = writeln!(out, "{name} {value}");
    }
}

/// Route a request (separated from I/O for testability). Returns
/// `(status, content type, body)`.
pub(crate) fn route(
    req: &Request,
    shared: &Shared,
    tx: &mpsc::Sender<(AgentSpec, f64)>,
) -> (u16, &'static str, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, JSON_CONTENT_TYPE, obj([("ok", true.into())]).dump()),
        ("GET", "/metrics") => {
            let agents = shared.agents.lock().unwrap();
            let done: Vec<f64> = agents.values().filter_map(|(_, _, j)| *j).collect();
            let mut out = String::new();
            prom_metric(
                &mut out,
                "justitia_agents_submitted",
                "counter",
                "Agents submitted since server start.",
                agents.len() as f64,
            );
            prom_metric(
                &mut out,
                "justitia_agents_completed",
                "counter",
                "Agents that finished every task.",
                done.len() as f64,
            );
            prom_metric(
                &mut out,
                "justitia_agents_in_flight",
                "gauge",
                "Agents submitted but not yet complete.",
                (agents.len() - done.len()) as f64,
            );
            prom_metric(
                &mut out,
                "justitia_jct_seconds_avg",
                "gauge",
                "Mean job completion time of completed agents.",
                crate::util::stats::mean(&done),
            );
            prom_metric(
                &mut out,
                "justitia_jct_seconds_p90",
                "gauge",
                "90th-percentile job completion time of completed agents.",
                crate::util::stats::percentile(&done, 90.0),
            );
            prom_metric(
                &mut out,
                "justitia_trace_available",
                "gauge",
                "1 when a /trace dump has been published, else 0.",
                if shared.trace.lock().unwrap().is_some() { 1.0 } else { 0.0 },
            );
            (200, PROMETHEUS_CONTENT_TYPE, out)
        }
        ("GET", "/trace") => match shared.trace.lock().unwrap().clone() {
            Some(dump) => (200, JSON_CONTENT_TYPE, dump),
            None => (
                404,
                JSON_CONTENT_TYPE,
                obj([(
                    "error",
                    "no trace captured (start the server with --trace)".into(),
                )])
                .dump(),
            ),
        },
        ("POST", "/agents") => {
            let body = String::from_utf8_lossy(&req.body);
            // The agents lock is the critical section for id assignment:
            // failed submissions must not burn ids, and concurrent POSTs
            // must not collide.
            let mut agents = shared.agents.lock().unwrap();
            let id = shared.next_id.load(Ordering::SeqCst);
            match parse_agent_submission(&body, id, 0x5eed) {
                Ok(spec) => {
                    shared.next_id.store(id + 1, Ordering::SeqCst);
                    agents.insert(
                        id,
                        (spec.class.short_name().into(), std::time::Instant::now(), None),
                    );
                    drop(agents);
                    // Price OUTSIDE the id-assignment critical section:
                    // predictor mode runs a TF-IDF + MLP forward pass
                    // (milliseconds), and holding the agents mutex across
                    // it would serialize every concurrent poll behind each
                    // submission. Predictor mode prices the agent from its
                    // prompt text (Ĉ_j); oracle mode keeps ground truth.
                    let cost = match &shared.predictor {
                        Some(p) => {
                            crate::predictor::Predictor::predict(p, spec.class, &spec.input_text)
                        }
                        None => CostModel::MemoryCentric.agent_cost(&spec),
                    };
                    let _ = tx.send((spec, cost));
                    (
                        202,
                        JSON_CONTENT_TYPE,
                        obj([("id", id.into()), ("predicted_cost", cost.into())]).dump(),
                    )
                }
                Err(e) => {
                    (400, JSON_CONTENT_TYPE, obj([("error", format!("{e:#}").into())]).dump())
                }
            }
        }
        ("GET", path) if path.starts_with("/agents/") => {
            let id: Option<u32> = path["/agents/".len()..].parse().ok();
            let agents = shared.agents.lock().unwrap();
            match id.and_then(|i| agents.get(&i).map(|e| (i, e.clone()))) {
                Some((i, (class, _, jct))) => (
                    200,
                    JSON_CONTENT_TYPE,
                    obj([
                        ("id", i.into()),
                        ("class", class.into()),
                        ("done", jct.is_some().into()),
                        ("jct_s", jct.map(Json::Num).unwrap_or(Json::Null)),
                    ])
                    .dump(),
                ),
                None => {
                    (404, JSON_CONTENT_TYPE, obj([("error", "no such agent".into())]).dump())
                }
            }
        }
        _ => (404, JSON_CONTENT_TYPE, obj([("error", "no such route".into())]).dump()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request_with_body() {
        let raw = b"POST /agents HTTP/1.1\r\nHost: x\r\nContent-Length: 15\r\n\r\n{\"class\": \"EV\"}";
        let mut cursor = std::io::Cursor::new(raw.to_vec());
        let req = parse_request(&mut cursor).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/agents");
        assert_eq!(req.body, b"{\"class\": \"EV\"}");
    }

    #[test]
    fn parses_request_without_body() {
        let raw = b"GET /metrics HTTP/1.1\r\n\r\n";
        let mut cursor = std::io::Cursor::new(raw.to_vec());
        let req = parse_request(&mut cursor).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn response_format() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", "{\"ok\":true}").unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Type: application/json"));
        assert!(s.contains("Content-Length: 11"));
        assert!(s.ends_with("{\"ok\":true}"));
    }

    #[test]
    fn submission_explicit_stages() {
        let body = r#"{"class": "DM", "stages": [[{"p": 10, "d": 4}, {"p": 8, "d": 3}], [{"p": 6, "d": 2}]]}"#;
        let spec = parse_agent_submission(body, 7, 1).unwrap();
        assert_eq!(spec.id, 7);
        assert_eq!(spec.class, AgentClass::DocumentMerging);
        assert_eq!(spec.n_tasks(), 3);
        assert_eq!(spec.tasks[1].prompt_tokens, 8);
        assert!(spec.tasks().all(|t| t.id.agent == 7));
        // The explicit-stages path builds a barrier DAG: the stage-1 task
        // depends on both stage-0 tasks.
        assert_eq!(spec.tasks[2].deps.len(), 2);
        assert!(spec.as_stages().is_some());
    }

    #[test]
    fn submission_generated_from_class() {
        let spec = parse_agent_submission(r#"{"class": "CC"}"#, 3, 1).unwrap();
        assert_eq!(spec.class, AgentClass::CodeChecking);
        assert!(spec.n_tasks() >= 2);
        // Clamped for the tiny artifact model.
        assert!(spec.tasks().all(|t| t.prompt_tokens <= 14 && t.decode_tokens <= 14));
    }

    #[test]
    fn submission_rejects_garbage() {
        assert!(parse_agent_submission("not json", 0, 1).is_err());
        assert!(parse_agent_submission(r#"{"class": "NOPE"}"#, 0, 1).is_err());
        assert!(parse_agent_submission(r#"{"class": "EV", "stages": []}"#, 0, 1).is_err());
    }

    #[test]
    fn predictor_mode_prices_submissions_with_the_model() {
        // With a predictor installed, the submit path must price agents
        // through it — an empty model predicts the 1.0 floor, which can
        // never coincide with the oracle cost of a generated agent.
        let shared = Shared {
            agents: Mutex::new(BTreeMap::new()),
            next_id: AtomicU32::new(0),
            predictor: Some(crate::predictor::PerClassPredictor {
                models: std::collections::HashMap::new(),
            }),
            trace: Mutex::new(None),
        };
        let (tx, rx) = mpsc::channel();
        let req = Request {
            method: "POST".into(),
            path: "/agents".into(),
            body: br#"{"class": "EV"}"#.to_vec(),
        };
        let (s, _, body) = route(&req, &shared, &tx);
        assert_eq!(s, 202);
        assert!(body.contains("predicted_cost"), "response must echo the prediction: {body}");
        let (spec, cost) = rx.try_recv().unwrap();
        assert_eq!(cost, 1.0);
        assert_ne!(
            cost,
            CostModel::MemoryCentric.agent_cost(&spec),
            "predictor-run tags must differ from the oracle's"
        );
    }

    #[test]
    fn routing_without_engine() {
        let shared = Shared {
            agents: Mutex::new(BTreeMap::new()),
            next_id: AtomicU32::new(0),
            predictor: None,
            trace: Mutex::new(None),
        };
        let (tx, rx) = mpsc::channel();
        let req = |m: &str, p: &str, b: &str| Request {
            method: m.into(),
            path: p.into(),
            body: b.as_bytes().to_vec(),
        };
        let (s, ct, _) = route(&req("GET", "/healthz", ""), &shared, &tx);
        assert_eq!((s, ct), (200, "application/json"));
        let (s, _, body) = route(&req("POST", "/agents", r#"{"class": "EV"}"#), &shared, &tx);
        assert_eq!(s, 202);
        assert!(body.contains("\"id\":0"));
        assert!(rx.try_recv().is_ok(), "spec forwarded to engine channel");
        let (s, _, body) = route(&req("GET", "/agents/0", ""), &shared, &tx);
        assert_eq!(s, 200);
        assert!(body.contains("\"done\":false"));
        let (s, _, _) = route(&req("GET", "/agents/99", ""), &shared, &tx);
        assert_eq!(s, 404);
        let (s, ct, body) = route(&req("GET", "/metrics", ""), &shared, &tx);
        assert_eq!(s, 200);
        assert_eq!(ct, PROMETHEUS_CONTENT_TYPE);
        assert!(body.contains("# TYPE justitia_agents_submitted counter"));
        assert!(body.contains("justitia_agents_submitted 1\n"));
        assert!(body.contains("justitia_agents_completed 0\n"));
        assert!(body.contains("justitia_agents_in_flight 1\n"));
        assert!(body.contains("justitia_jct_seconds_avg 0\n"), "no completions yet: {body}");
        assert!(body.contains("justitia_trace_available 0\n"));
        let (s, _, _) = route(&req("GET", "/nope", ""), &shared, &tx);
        assert_eq!(s, 404);
    }

    #[test]
    fn trace_endpoint_serves_published_dump_or_404() {
        let shared = Shared {
            agents: Mutex::new(BTreeMap::new()),
            next_id: AtomicU32::new(0),
            predictor: None,
            trace: Mutex::new(None),
        };
        let (tx, _rx) = mpsc::channel();
        let req = Request { method: "GET".into(), path: "/trace".into(), body: Vec::new() };
        let (s, _, body) = route(&req, &shared, &tx);
        assert_eq!(s, 404);
        assert!(body.contains("--trace"));
        // The engine thread publishes; the route serves the snapshot as-is.
        *shared.trace.lock().unwrap() = Some("{\"traceEvents\":[]}".into());
        let (s, ct, body) = route(&req, &shared, &tx);
        assert_eq!((s, ct), (200, "application/json"));
        assert_eq!(body, "{\"traceEvents\":[]}");
        let mreq = Request { method: "GET".into(), path: "/metrics".into(), body: Vec::new() };
        let (_, _, metrics) = route(&mreq, &shared, &tx);
        assert!(metrics.contains("justitia_trace_available 1\n"));
    }
}
