//! simlint — determinism-contract static analysis for the Justitia tree.
//!
//! The simulator's load-bearing invariant is *deterministic replay*: every
//! fairness number in the paper reproduction is backed by bit-identity
//! property suites, so any unordered-map iteration, wall-clock read, or
//! NaN-unsafe float comparison on the replay path silently invalidates the
//! results. simlint machine-checks that contract (rules R1–R4, see
//! [`rules`] and DESIGN.md §16) and runs as a blocking CI gate.
//!
//! Library layout: [`lexer`] turns Rust source into a token stream plus
//! `simlint::allow` annotations; [`rules`] implements the four rules over
//! that stream; [`run`] walks a source root and aggregates a [`Report`].

pub mod lexer;
pub mod rules;

use rules::{Diag, FileReport};
use std::path::{Path, PathBuf};

/// What to lint.
pub struct Options {
    /// Source root (normally `rust/src`).
    pub root: PathBuf,
    /// Path to the R4 knob-default manifest; `None` skips R4.
    pub manifest: Option<PathBuf>,
}

/// Aggregated lint outcome across the tree.
#[derive(Debug, Default)]
pub struct Report {
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Unsuppressed violations (CI-blocking).
    pub violations: Vec<Diag>,
    /// Sites accepted via a justified `simlint::allow` annotation.
    pub allowed: Vec<Diag>,
    /// Annotations that suppress nothing (warnings, non-blocking).
    pub stale: Vec<Diag>,
}

impl Report {
    /// The one-line summary kick-tires and CI print.
    pub fn summary(&self) -> String {
        format!(
            "simlint: {} files, {} violations, {} allowed (annotated), {} stale annotations",
            self.files_scanned,
            self.violations.len(),
            self.allowed.len(),
            self.stale.len()
        )
    }

    fn absorb(&mut self, fr: FileReport) {
        self.violations.extend(fr.violations);
        self.allowed.extend(fr.allowed);
        self.stale.extend(fr.stale);
    }
}

/// Lint every `.rs` file under `opts.root` and cross-check the knob
/// manifest. I/O errors (unreadable root, missing manifest) are reported
/// as `Err`; lint findings — including a missing `Config` impl — are data
/// in the `Ok` report.
pub fn run(opts: &Options) -> Result<Report, String> {
    let mut files = Vec::new();
    walk(&opts.root, &mut files).map_err(|e| format!("scan {}: {e}", opts.root.display()))?;
    files.sort();

    let mut report = Report::default();
    for path in &files {
        let rel = rel_path(&opts.root, path);
        let src = std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        report.files_scanned += 1;
        report.absorb(rules::lint_file(&rel, &src));
    }

    if let Some(manifest) = &opts.manifest {
        let manifest_src = std::fs::read_to_string(manifest)
            .map_err(|e| format!("read {}: {e}", manifest.display()))?;
        let config_path = opts.root.join("config/mod.rs");
        match std::fs::read_to_string(&config_path) {
            Ok(config_src) => {
                let manifest_rel = manifest
                    .file_name()
                    .map(|n| n.to_string_lossy().into_owned())
                    .unwrap_or_else(|| manifest.display().to_string());
                report.violations.extend(rules::r4_knob_defaults(
                    "config/mod.rs",
                    &config_src,
                    &manifest_rel,
                    &manifest_src,
                ));
            }
            // Fixture trees have no config module; R4 only applies when
            // the real crate layout is present.
            Err(_) => {}
        }
    }

    // Deterministic output order, naturally: files were sorted and rules
    // emit in token order, but R4 appends after the walk — keep the final
    // stream sorted by (file, line) so CI diffs are stable.
    report.violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    report.stale.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(report)
}

fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}
