//! Deterministic hash tokenizer (substitution T5 in DESIGN.md).
//!
//! The real system tokenizes with the served model's tokenizer; for
//! scheduling what matters is the *token count* and a stable text->ids map
//! for TF-IDF. This tokenizer splits on whitespace/punctuation, then maps
//! each word to an id by FNV-1a hash into a fixed vocabulary, matching the
//! vocab size of the tiny transformer artifact so the same ids drive the
//! PJRT model.

/// FNV-1a 64-bit hash.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash tokenizer with a fixed vocab size. Ids 0..RESERVED are reserved
/// (0 = pad, 1 = bos, 2 = eos).
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// Total id space, including the reserved ids.
    pub vocab_size: u32,
}

/// Padding token id.
pub const PAD: u32 = 0;
/// Beginning-of-sequence token id.
pub const BOS: u32 = 1;
/// End-of-sequence token id.
pub const EOS: u32 = 2;
const RESERVED: u32 = 3;

impl Tokenizer {
    /// Tokenizer over `vocab_size` ids.
    pub fn new(vocab_size: u32) -> Self {
        assert!(vocab_size > RESERVED + 1);
        Tokenizer { vocab_size }
    }

    /// Split text into word pieces: runs of alphanumerics, or single
    /// punctuation characters. Whitespace separates.
    pub fn words(text: &str) -> Vec<&str> {
        let mut out = Vec::new();
        let bytes = text.as_bytes();
        let mut i = 0;
        while i < bytes.len() {
            let c = bytes[i] as char;
            if c.is_ascii_whitespace() {
                i += 1;
            } else if c.is_ascii_alphanumeric() || c == '_' {
                let start = i;
                while i < bytes.len() && ((bytes[i] as char).is_ascii_alphanumeric() || bytes[i] == b'_') {
                    i += 1;
                }
                out.push(&text[start..i]);
            } else {
                // Single non-alnum char (punctuation or a UTF-8 lead byte:
                // consume the full codepoint).
                let ch_len = utf8_len(bytes[i]);
                out.push(&text[i..i + ch_len]);
                i += ch_len;
            }
        }
        out
    }

    /// Map a word to a token id (stable across runs/processes).
    #[inline]
    pub fn word_id(&self, word: &str) -> u32 {
        RESERVED + (fnv1a(word.as_bytes()) % (self.vocab_size - RESERVED) as u64) as u32
    }

    /// Encode text to ids, prefixed with BOS.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids = vec![BOS];
        ids.extend(Self::words(text).iter().map(|w| self.word_id(w)));
        ids
    }

    /// Number of tokens `encode` would produce.
    pub fn count(&self, text: &str) -> usize {
        1 + Self::words(text).len()
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn words_split() {
        assert_eq!(Tokenizer::words("hello world"), vec!["hello", "world"]);
        assert_eq!(Tokenizer::words("a,b.c"), vec!["a", ",", "b", ".", "c"]);
        assert_eq!(Tokenizer::words("  x  "), vec!["x"]);
        assert_eq!(Tokenizer::words(""), Vec::<&str>::new());
        assert_eq!(Tokenizer::words("foo_bar2 baz"), vec!["foo_bar2", "baz"]);
    }

    #[test]
    fn encode_deterministic_and_in_range() {
        let t = Tokenizer::new(2048);
        let a = t.encode("summarize this document chunk please");
        let b = t.encode("summarize this document chunk please");
        assert_eq!(a, b);
        assert_eq!(a[0], BOS);
        assert!(a.iter().all(|&id| id < 2048));
        assert!(a[1..].iter().all(|&id| id >= 3));
    }

    #[test]
    fn same_word_same_id() {
        let t = Tokenizer::new(1024);
        assert_eq!(t.word_id("merge"), t.word_id("merge"));
    }

    #[test]
    fn count_matches_encode() {
        let t = Tokenizer::new(512);
        let s = "verify the claim: 2+2=4 .";
        assert_eq!(t.count(s), t.encode(s).len());
    }

    #[test]
    fn unicode_does_not_panic() {
        let t = Tokenizer::new(512);
        let ids = t.encode("héllo 😀 wörld");
        assert!(ids.len() >= 4);
    }
}
