//! Starvation demo (Fig. 9): an MRS "elephant" agent vs a sustained stream
//! of small "mice" agents, under SRJF and Justitia.
//!
//! SRJF always ranks the elephant last, so a continuous mice stream delays
//! it indefinitely; Justitia fixes the elephant's virtual finish tag at
//! arrival, so once V(t) passes it, later mice queue behind — the delay is
//! bounded (Theorem B.1), regardless of how many mice arrive.
//!
//! Run: `cargo run --release --example starvation`

use justitia::config::Policy;

fn main() {
    println!("One MapReduce-Summarization elephant + N mice (KBQAV/CC/ALFWI stream)\n");
    let counts = [0usize, 50, 100, 200, 400];
    let rows = justitia::experiments::fig9(&counts, 7);
    let jct = |p: Policy, n: usize| {
        rows.iter().find(|r| r.policy == p && r.n_mice == n).unwrap().elephant_jct
    };

    println!("{:>6} | {:>10} | {:>10}", "mice", "SRJF", "Justitia");
    println!("{:->6}-+-{:->10}-+-{:->10}", "", "", "");
    for &n in &counts {
        println!("{:>6} | {:>9.1}s | {:>9.1}s", n, jct(Policy::Srjf, n), jct(Policy::Justitia, n));
    }

    let srjf_g = jct(Policy::Srjf, 400) / jct(Policy::Srjf, 0);
    let just_g = jct(Policy::Justitia, 400) / jct(Policy::Justitia, 0);
    println!(
        "\nelephant slowdown at 400 mice:  SRJF {srjf_g:.1}x (unbounded growth)  \
         Justitia {just_g:.1}x (plateau — Thm B.1 bound)"
    );
}
