//! Churn conservation property (DESIGN.md §14): under *random* failure /
//! drain / join schedules — composed with every scheduler and the
//! {prefix cache, DAG + spawning, chunked prefill, preemption-auto} knob
//! draws — the cluster must conserve work and memory:
//!
//! * no agent is lost or duplicated (every agent completes exactly once in
//!   the merged metrics),
//! * KV page accounting balances on every surviving replica, and the device
//!   pool drains to zero at end of run (prefix cache off; the cache pins
//!   pages by design),
//! * the whole churn run is replay-deterministic for a fixed seed.
//!
//! Random schedules spare replica 0 ([`FailureSchedule::random`]), so every
//! generated scenario is guaranteed completable.

use justitia::cluster::{ClusterDispatcher, FailureSchedule, Placement};
use justitia::config::{BackendProfile, Config, Policy, PreemptionMode};
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::util::prop::{check, Config as PropConfig, Strategy};
use justitia::util::rng::Rng;
use justitia::workload::test_support::dag_agent;
use justitia::workload::{AgentSpec, SpawnSpec, Suite};

#[derive(Clone, Debug)]
struct ChurnScenario {
    agents: Vec<AgentSpec>,
    pages: u64,
    page_size: u32,
    prefix_cache: bool,
    spawn: bool,
    chunked: bool,
    preempt_auto: bool,
    host_tokens: Option<u64>,
    swap_bw: f64,
    /// Replica pool size the random schedule churns over.
    n_replicas: usize,
    /// Seed for [`FailureSchedule::random`].
    churn_seed: u64,
    /// Number of churn events drawn.
    n_events: usize,
}

struct ChurnStrategy;

impl Strategy for ChurnStrategy {
    type Value = ChurnScenario;

    fn generate(&self, rng: &mut Rng) -> ChurnScenario {
        let page_size = 8u32;
        let pages = rng.range_u64(24, 48);
        let m_tokens = pages * page_size as u64;
        let n_agents = rng.range_u64(2, 7) as usize;
        let spawn = rng.chance(0.5);
        let mut agents = Vec::with_capacity(n_agents);
        let mut t = 0.0;
        for id in 0..n_agents {
            t += rng.exponential(0.05);
            let n_tasks = rng.range_u64(1, 5) as usize;
            let mut tasks = Vec::with_capacity(n_tasks);
            for i in 0..n_tasks {
                let p = rng.range_u64(2, m_tokens / 3) as u32;
                let d = rng.range_u64(1, 16) as u32;
                let deps = if i > 0 && rng.chance(0.3) {
                    vec![rng.below(i as u64) as u32]
                } else {
                    Vec::new()
                };
                tasks.push((p, d, deps));
            }
            let mut a = dag_agent(id as u32, t, tasks);
            if spawn {
                a.spawn = Some(SpawnSpec {
                    prob: 0.6,
                    branch: 2,
                    max_depth: 1,
                    seed: rng.next_u64(),
                });
            }
            agents.push(a);
        }
        ChurnScenario {
            agents,
            pages,
            page_size,
            prefix_cache: rng.chance(0.5),
            spawn,
            chunked: rng.chance(0.5),
            preempt_auto: rng.chance(0.5),
            host_tokens: match rng.below(3) {
                0 => None,
                1 => Some(m_tokens / 4),
                _ => Some(0),
            },
            swap_bw: if rng.chance(0.5) { 1000.0 } else { 0.0 },
            n_replicas: rng.range_u64(2, 4) as usize,
            churn_seed: rng.next_u64(),
            n_events: rng.range_u64(1, 6) as usize,
        }
    }

    fn shrink(&self, v: &ChurnScenario) -> Vec<ChurnScenario> {
        let mut out = Vec::new();
        if v.agents.len() > 1 {
            let mut w = v.clone();
            w.agents.pop();
            out.push(w);
        }
        if v.n_events > 1 {
            let mut w = v.clone();
            w.n_events -= 1;
            out.push(w);
        }
        for knob in 0..4 {
            let mut w = v.clone();
            let on = match knob {
                0 => std::mem::replace(&mut w.prefix_cache, false),
                1 => {
                    let on = w.spawn;
                    w.spawn = false;
                    for a in &mut w.agents {
                        a.spawn = None;
                    }
                    on
                }
                2 => std::mem::replace(&mut w.chunked, false),
                _ => std::mem::replace(&mut w.preempt_auto, false),
            };
            if on {
                out.push(w);
            }
        }
        out
    }
}

fn config_for(sc: &ChurnScenario) -> Config {
    let mut cfg = Config::default();
    cfg.backend = BackendProfile {
        name: "prop-churn".into(),
        kv_tokens: sc.pages * sc.page_size as u64,
        page_size: sc.page_size,
        alpha: 1.0,
        beta_prefill: 1e-3,
        beta_decode: 0.0,
        swap_cost_per_token: 0.0,
        beta_mixed: 0.0,
        host_kv_tokens: sc.host_tokens,
        swap_bw_tokens_per_sec: sc.swap_bw,
    };
    cfg.max_batch = 64;
    cfg.prefix_cache = sc.prefix_cache;
    if sc.preempt_auto {
        cfg.preemption = PreemptionMode::Auto;
    }
    if sc.chunked {
        cfg.chunked_prefill = true;
        cfg.prefill_chunk = 16;
        cfg.max_batched_tokens = 48;
    }
    cfg
}

fn suite_for(sc: &ChurnScenario) -> Suite {
    let mut suite = Suite::new(sc.agents.clone());
    if sc.prefix_cache {
        justitia::workload::trace::annotate_families(&mut suite, 2, 16, 0xfa7e);
    }
    suite
}

fn engine_for(cfg: &Config, policy: Policy) -> Engine<SimBackend> {
    let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
    Engine::new(cfg, sched, SimBackend::unit_time())
}

/// One churn replay. Returns the merged-run fingerprint and runs the
/// per-replica conservation checks.
fn replay(
    sc: &ChurnScenario,
    policy: Policy,
) -> Result<(f64, Vec<(u32, f64)>, (u64, u64, u64)), String> {
    let cfg = config_for(sc);
    let suite = suite_for(sc);
    let horizon = suite.agents.last().map(|a| a.arrival).unwrap_or(0.0) + 30.0;
    let schedule = FailureSchedule::random(sc.churn_seed, sc.n_replicas, horizon, sc.n_events);
    let replicas = (0..sc.n_replicas).map(|_| engine_for(&cfg, policy)).collect();
    let mut cluster =
        ClusterDispatcher::new(replicas, Placement::ClusterVtime, cfg.backend.kv_tokens, 1.0);
    let model = justitia::cost::CostModel::MemoryCentric;
    let makespan =
        cluster.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, || {
            engine_for(&cfg, policy)
        });

    let m = cluster.merged_metrics();
    // Conservation of agents: each completes exactly once in the merge.
    if m.completed_agents() != suite.len() {
        return Err(format!(
            "{policy:?}: {}/{} agents completed under schedule [{}]",
            m.completed_agents(),
            suite.len(),
            schedule.to_dsl()
        ));
    }
    let jcts = m.jcts();
    if jcts.len() != suite.len() {
        return Err(format!(
            "{policy:?}: {} JCT entries for {} agents (lost or duplicated)",
            jcts.len(),
            suite.len()
        ));
    }
    // Conservation of memory on every surviving replica.
    for r in 0..cluster.n_replicas() {
        let e = cluster.replica(r);
        e.check_kv_invariants().map_err(|err| format!("{policy:?}: replica {r}: {err}"))?;
        if sc.chunked {
            e.check_chunked_accounting()
                .map_err(|err| format!("{policy:?}: replica {r}: {err}"))?;
        }
        if !sc.prefix_cache && e.kv.device_tokens() != 0 {
            return Err(format!(
                "{policy:?}: replica {r} holds {} device tokens after completion",
                e.kv.device_tokens()
            ));
        }
    }
    Ok((makespan, jcts, cluster.churn_counters()))
}

#[test]
fn prop_churn_conserves_agents_and_kv_across_schedulers() {
    let cfg = PropConfig { cases: prop_cases(20), seed: 0xc4a0_5eed, max_shrink_steps: 60 };
    check(&cfg, &ChurnStrategy, |sc| {
        for policy in [
            Policy::Fcfs,
            Policy::Sjf,
            Policy::AgentFcfs,
            Policy::Vtc,
            Policy::Srjf,
            Policy::Justitia,
        ] {
            replay(sc, policy)?;
        }
        Ok(())
    });
}

#[test]
fn prop_churn_replay_is_deterministic() {
    let cfg = PropConfig { cases: prop_cases(12), seed: 0xd373_c4a0, max_shrink_steps: 40 };
    check(&cfg, &ChurnStrategy, |sc| {
        for policy in [Policy::Fcfs, Policy::Justitia] {
            let a = replay(sc, policy)?;
            let b = replay(sc, policy)?;
            if a != b {
                return Err(format!(
                    "{policy:?}: same (suite, schedule, seed) diverged across replays \
                     (makespan {} vs {}, counters {:?} vs {:?})",
                    a.0, b.0, a.2, b.2
                ));
            }
        }
        Ok(())
    });
}

fn prop_cases(default: usize) -> usize {
    std::env::var("JUSTITIA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}
