"""Layer-1 Pallas kernel: paged attention for one decode step.

The compute hot-spot of the serving engine: each running sequence attends
from its single new-token query to its whole KV history, which lives
scattered across fixed-size pages of the global KV pool (vLLM paging). The
Rust KV allocator owns the block tables; this kernel consumes them.

HARDWARE ADAPTATION (DESIGN.md §3): vLLM's CUDA kernel gives each (seq, head)
a threadblock that gathers KV pages from HBM via a per-block pointer array
and reduces with warp shuffles. On TPU the same insight — keep the page
gather off the critical path of the softmax — maps to a BlockSpec-driven
HBM→VMEM schedule: the grid iterates (sequence, kv-page); each step pulls one
(page_size, H·D) KV tile into VMEM and folds it into an online-softmax
accumulator held in VMEM scratch. The MXU does the q·kᵀ and p·v contractions;
the online softmax (running max m, normalizer l) replaces warp-level
reductions. Block tables enter as a small int32 input, the TPU analogue of
the pointer array.

Lowered with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is estimated from the VMEM footprint and
MXU utilization of these block shapes in DESIGN.md / EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _paged_attn_kernel(
    # scalar-ish inputs (blocked per grid step)
    block_tables_ref,  # [1, max_pages] int32 — this sequence's page table
    seq_len_ref,       # [1] int32 — this sequence's context length
    q_ref,             # [1, H, D]
    k_pages_ref,       # [P, page, H, D] (full pool, resident)
    v_pages_ref,       # [P, page, H, D]
    o_ref,             # [1, H, D]
    *,
    page_size: int,
    max_pages: int,
):
    h = q_ref.shape[1]
    d = q_ref.shape[2]
    q = q_ref[0].astype(jnp.float32)  # [H, D]
    seq_len = seq_len_ref[0]

    def body(p_idx, carry):
        m_prev, l_prev, acc = carry  # [H,1], [H,1], [H,D]
        page_id = block_tables_ref[0, p_idx]
        # HBM→VMEM tile pull: one KV page, all heads.
        k_tile = pl.load(
            k_pages_ref, (pl.dslice(page_id, 1), slice(None), slice(None), slice(None))
        )[0].astype(jnp.float32)  # [page, H, D]
        v_tile = pl.load(
            v_pages_ref, (pl.dslice(page_id, 1), slice(None), slice(None), slice(None))
        )[0].astype(jnp.float32)

        # Scores for this page: [H, page] (MXU contraction over D).
        s = jnp.einsum("hd,phd->hp", q, k_tile) * (1.0 / (d**0.5))
        # Mask positions beyond the sequence length.
        pos = p_idx * page_size + jax.lax.iota(jnp.int32, page_size)
        s = jnp.where((pos < seq_len)[None, :], s, NEG_INF)

        # Online softmax update.
        m_cur = jnp.max(s, axis=1, keepdims=True)  # [H,1]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p_exp = jnp.exp(s - m_new)  # [H, page]
        l_new = l_prev * alpha + jnp.sum(p_exp, axis=1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("hp,phd->hd", p_exp, v_tile)
        return m_new, l_new, acc_new

    n_pages = (seq_len + page_size - 1) // page_size
    m0 = jnp.full((h, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((h, 1), jnp.float32)
    acc0 = jnp.zeros((h, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, seq_lens, *, interpret=True):
    """Paged attention over a batch of decoding sequences.

    Args:
      q:            [B, H, D]
      k_pages:      [P, page, H, D]
      v_pages:      [P, page, H, D]
      block_tables: [B, max_pages] int32
      seq_lens:     [B] int32
      interpret:    must stay True on CPU PJRT (Mosaic unavailable).

    Returns:
      [B, H, D]
    """
    b, h, d = q.shape
    n_pages_total, page_size, _, _ = k_pages.shape
    max_pages = block_tables.shape[1]

    kernel = functools.partial(
        _paged_attn_kernel, page_size=page_size, max_pages=max_pages
    )
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, max_pages), lambda i: (i, 0)),          # block table row
            pl.BlockSpec((1,), lambda i: (i,)),                       # seq len
            pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),             # q row
            pl.BlockSpec((n_pages_total, page_size, h, d), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((n_pages_total, page_size, h, d), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, q, k_pages, v_pages)


def vmem_footprint_bytes(page_size: int, n_heads: int, d_head: int, dtype_bytes: int = 4):
    """Estimated VMEM working set per grid step (perf model, DESIGN.md §Perf):
    one K tile + one V tile + q + accumulators."""
    tile = page_size * n_heads * d_head * dtype_bytes
    q = n_heads * d_head * dtype_bytes
    acc = n_heads * (d_head + 2) * 4
    return 2 * tile + q + acc


def mxu_flops_per_step(page_size: int, n_heads: int, d_head: int):
    """MXU MACs per grid step: q·kᵀ + p·v contractions."""
    return 2 * 2 * page_size * n_heads * d_head
