//! Differential test for the event-driven engine core (ISSUE 6 tentpole):
//! the calendar-queue driver (`cfg.event_core = true`) must replay EXACTLY
//! the same simulation as the legacy tick loop — bit-identical per-agent
//! JCTs, per-task schedule order (admit/complete times), iteration counts,
//! and counter metrics — across all six schedulers and every knob draw:
//! {prefix cache, DAG + dynamic spawning, chunked prefill, preemption auto}
//! over randomized tight-pool workloads.
//!
//! The legacy loop is the oracle for one PR (it predates the event core and
//! is untouched by it); any divergence here is a bug in the event core's
//! dirty tracking, cached batch composition, or clock advancement.

use justitia::cluster::{ClusterDispatcher, FailureSchedule, Placement};
use justitia::config::{BackendProfile, Config, Policy, PreemptionMode};
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::util::prop::{check, Config as PropConfig, Strategy};
use justitia::util::rng::Rng;
use justitia::workload::test_support::dag_agent;
use justitia::workload::{AgentSpec, SpawnSpec, Suite};

/// A randomized workload plus the four knob draws the event core must agree
/// with the tick loop under.
#[derive(Clone, Debug)]
struct IdentityScenario {
    agents: Vec<AgentSpec>,
    pages: u64,
    page_size: u32,
    /// Radix-tree prefix cache on, with the suite annotated into families.
    prefix_cache: bool,
    /// Agents carry spawn rules (dynamic task spawning at runtime).
    spawn: bool,
    /// Chunked prefill + token-budget batching.
    chunked: bool,
    /// `PreemptionMode::Auto` with a bounded host pool (else default Swap).
    preempt_auto: bool,
    host_tokens: Option<u64>,
    swap_bw: f64,
    /// Seed for the random churn schedule the cluster identity test draws
    /// ([`FailureSchedule::random`]); ignored by the single-engine tests.
    churn_seed: u64,
}

struct IdentityStrategy;

impl Strategy for IdentityStrategy {
    type Value = IdentityScenario;

    fn generate(&self, rng: &mut Rng) -> IdentityScenario {
        let page_size = 8u32;
        let pages = rng.range_u64(24, 48);
        let m_tokens = pages * page_size as u64;
        let n_agents = rng.range_u64(2, 7) as usize;
        let spawn = rng.chance(0.5);
        let mut agents = Vec::with_capacity(n_agents);
        let mut t = 0.0;
        for id in 0..n_agents {
            t += rng.exponential(0.05);
            let n_tasks = rng.range_u64(1, 5) as usize;
            let mut tasks = Vec::with_capacity(n_tasks);
            for i in 0..n_tasks {
                // Prompts up to a third of the pool force preemption traffic
                // while every (re-entered) sequence still fits an empty pool.
                let p = rng.range_u64(2, m_tokens / 3) as u32;
                let d = rng.range_u64(1, 16) as u32;
                let deps = if i > 0 && rng.chance(0.3) {
                    vec![rng.below(i as u64) as u32]
                } else {
                    Vec::new()
                };
                tasks.push((p, d, deps));
            }
            let mut a = dag_agent(id as u32, t, tasks);
            if spawn {
                a.spawn = Some(SpawnSpec {
                    prob: 0.6,
                    branch: 2,
                    max_depth: 1,
                    seed: rng.next_u64(),
                });
            }
            agents.push(a);
        }
        IdentityScenario {
            agents,
            pages,
            page_size,
            prefix_cache: rng.chance(0.5),
            spawn,
            chunked: rng.chance(0.5),
            preempt_auto: rng.chance(0.5),
            host_tokens: match rng.below(3) {
                0 => None,
                1 => Some(m_tokens / 4),
                _ => Some(0),
            },
            swap_bw: if rng.chance(0.5) { 1000.0 } else { 0.0 },
            churn_seed: rng.next_u64(),
        }
    }

    fn shrink(&self, v: &IdentityScenario) -> Vec<IdentityScenario> {
        let mut out = Vec::new();
        if v.agents.len() > 1 {
            let mut w = v.clone();
            w.agents.pop();
            out.push(w);
        }
        for knob in 0..4 {
            let mut w = v.clone();
            let on = match knob {
                0 => std::mem::replace(&mut w.prefix_cache, false),
                1 => {
                    let on = w.spawn;
                    w.spawn = false;
                    for a in &mut w.agents {
                        a.spawn = None;
                    }
                    on
                }
                2 => std::mem::replace(&mut w.chunked, false),
                _ => std::mem::replace(&mut w.preempt_auto, false),
            };
            if on {
                out.push(w);
            }
        }
        out
    }
}

fn config_for(sc: &IdentityScenario) -> Config {
    let mut cfg = Config::default();
    cfg.backend = BackendProfile {
        name: "prop-evcore".into(),
        kv_tokens: sc.pages * sc.page_size as u64,
        page_size: sc.page_size,
        alpha: 1.0,
        beta_prefill: 1e-3,
        beta_decode: 0.0,
        swap_cost_per_token: 0.0,
        beta_mixed: 0.0,
        host_kv_tokens: sc.host_tokens,
        swap_bw_tokens_per_sec: sc.swap_bw,
    };
    cfg.max_batch = 64;
    cfg.prefix_cache = sc.prefix_cache;
    if sc.preempt_auto {
        cfg.preemption = PreemptionMode::Auto;
    }
    if sc.chunked {
        cfg.chunked_prefill = true;
        cfg.prefill_chunk = 16;
        cfg.max_batched_tokens = 48;
    }
    cfg
}

fn suite_for(sc: &IdentityScenario) -> Suite {
    let mut suite = Suite::new(sc.agents.clone());
    if sc.prefix_cache {
        // Families of 2 sharing a 2-page prefix: enough to exercise dedup.
        justitia::workload::trace::annotate_families(&mut suite, 2, 16, 0xfa7e);
    }
    suite
}

/// Everything the engine observably computed, in exact (bit-level) form.
/// Schedule order is pinned by the per-task admit/complete time vectors over
/// the full dynamic task set (spawn expansion included).
type Trace = (f64, Vec<(u32, f64)>, Vec<(u32, u32, Option<f64>, Option<f64>)>, [u64; 7]);

fn replay(sc: &IdentityScenario, policy: Policy, event_core: bool) -> Trace {
    let mut cfg = config_for(sc);
    cfg.event_core = event_core;
    let suite = suite_for(sc);
    let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
    let mut engine = Engine::new(&cfg, sched, SimBackend::unit_time());
    let model = justitia::cost::CostModel::MemoryCentric;
    let makespan = engine.run_suite(&suite, |a| model.agent_cost(a));
    let m = &engine.metrics;
    let mut tasks = Vec::new();
    for a in &suite.agents {
        for t in a.tasks.iter().chain(a.expand_spawns().iter()) {
            tasks.push((
                t.id.agent,
                t.id.index,
                m.task_admit_time(t.id),
                m.task_complete_time(t.id),
            ));
        }
    }
    (
        makespan,
        m.jcts(),
        tasks,
        [
            m.iterations(),
            m.swap_out_count(),
            m.recompute_count(),
            m.prefill_tokens_executed(),
            m.prefix_hits(),
            m.spawned_tasks(),
            m.prefill_stalls(),
        ],
    )
}

#[test]
fn prop_event_core_is_bit_identical_to_tick_loop() {
    let cfg = PropConfig { cases: prop_cases(25), seed: 0xca1e_17da, max_shrink_steps: 60 };
    check(&cfg, &IdentityStrategy, |sc| {
        for policy in [
            Policy::Fcfs,
            Policy::Sjf,
            Policy::AgentFcfs,
            Policy::Vtc,
            Policy::Srjf,
            Policy::Justitia,
        ] {
            let tick = replay(sc, policy, false);
            let event = replay(sc, policy, true);
            if tick != event {
                let what = if tick.1 != event.1 {
                    "per-agent JCTs"
                } else if tick.2 != event.2 {
                    "per-task schedule order"
                } else if tick.3 != event.3 {
                    "counter metrics"
                } else {
                    "makespan"
                };
                return Err(format!(
                    "{policy:?}: event core diverged from tick loop on {what} \
                     (tick counters {:?} vs event {:?}, makespan {} vs {})",
                    tick.3, event.3, tick.0, event.0,
                ));
            }
        }
        Ok(())
    });
}

/// The default configuration (every knob off) must also agree — this is the
/// exact path `cfg.event_core` toggles in production runs.
#[test]
fn prop_event_core_identity_with_default_knobs() {
    let cfg = PropConfig { cases: prop_cases(15), seed: 0xdeaf_0001, max_shrink_steps: 40 };
    check(&cfg, &IdentityStrategy, |sc| {
        let mut sc = sc.clone();
        sc.prefix_cache = false;
        sc.chunked = false;
        sc.preempt_auto = false;
        sc.host_tokens = None;
        for policy in [Policy::Fcfs, Policy::Justitia] {
            let tick = replay(&sc, policy, false);
            let event = replay(&sc, policy, true);
            if tick != event {
                return Err(format!(
                    "{policy:?}: default-knob divergence (tick {:?} vs event {:?})",
                    tick.3, event.3
                ));
            }
        }
        Ok(())
    });
}

/// Merged-cluster fingerprint of one churn replay on the given core.
fn replay_churn(
    sc: &IdentityScenario,
    policy: Policy,
    event_core: bool,
) -> (f64, Vec<(u32, f64)>, (u64, u64, u64), [u64; 4]) {
    let mut cfg = config_for(sc);
    cfg.event_core = event_core;
    let suite = suite_for(sc);
    let horizon = suite.agents.last().map(|a| a.arrival).unwrap_or(0.0) + 30.0;
    let schedule = FailureSchedule::random(sc.churn_seed, 3, horizon, 4);
    let engine_for = |cfg: &Config| {
        let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
        Engine::new(cfg, sched, SimBackend::unit_time())
    };
    let replicas = (0..3).map(|_| engine_for(&cfg)).collect();
    let mut cluster =
        ClusterDispatcher::new(replicas, Placement::ClusterVtime, cfg.backend.kv_tokens, 1.0);
    let model = justitia::cost::CostModel::MemoryCentric;
    let makespan =
        cluster.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, || engine_for(&cfg));
    let m = cluster.merged_metrics();
    (
        makespan,
        m.jcts(),
        cluster.churn_counters(),
        [m.iterations(), m.swap_out_count(), m.recompute_count(), m.prefill_tokens_executed()],
    )
}

/// Churn runs drive every replica through `Engine::step`, whose batch
/// composition is exactly the machinery `event_core` rewires — so a random
/// crash/drain/join schedule over a 3-replica cluster (recovery fold,
/// re-placement, drains and joins included) must replay bit-identically on
/// both cores, for every scheduler.
#[test]
fn prop_event_core_identity_under_churn() {
    let cfg = PropConfig { cases: prop_cases(10), seed: 0xc4a0_e7c0, max_shrink_steps: 40 };
    check(&cfg, &IdentityStrategy, |sc| {
        for policy in [
            Policy::Fcfs,
            Policy::Sjf,
            Policy::AgentFcfs,
            Policy::Vtc,
            Policy::Srjf,
            Policy::Justitia,
        ] {
            let tick = replay_churn(sc, policy, false);
            let event = replay_churn(sc, policy, true);
            if tick != event {
                return Err(format!(
                    "{policy:?}: cores diverged under churn (makespan {} vs {}, \
                     churn counters {:?} vs {:?}, metric counters {:?} vs {:?})",
                    tick.0, event.0, tick.2, event.2, tick.3, event.3
                ));
            }
        }
        Ok(())
    });
}

fn prop_cases(default: usize) -> usize {
    std::env::var("JUSTITIA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}
