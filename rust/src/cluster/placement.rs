//! Placement policies: which replica an arriving agent is routed to.
//!
//! The cluster-level fairness question (left open by VTC and Equinox for
//! multi-server deployments) is *where* to put an agent so that Justitia's
//! per-replica selective pampering composes into a globally fair schedule.
//! Three policies are provided:
//!
//! * [`Placement::RoundRobin`] — the classic strawman: agent k goes to
//!   replica k mod N. Balances *counts*, not *work*: one DocMerging elephant
//!   weighs as much as a thousand EquationVerification mice.
//! * [`Placement::LeastLoaded`] — route to the replica with the smallest
//!   outstanding *predicted KV cost* (a fluid backlog that drains at the
//!   replica's nominal GPS service rate `M × rate_scale`). Balances work,
//!   but ignores fair-queuing order.
//! * [`Placement::ClusterVtime`] — route to the replica whose GPS fluid
//!   reference would finish the agent *earliest in real time*: each replica
//!   keeps a mirror [`VirtualClock`], and the dispatcher simulates the
//!   hypothetical arrival on every mirror
//!   ([`VirtualClock::hypothetical_gps_finish`]). Because Justitia serves
//!   agents in GPS-finish order, minimizing the GPS finish tag across
//!   replicas keeps selective pampering globally fair — the cluster behaves
//!   like one big GPS server partitioned on the fly.
//!
//! All three are deterministic: ties break toward the lowest replica index,
//! so a cluster run is exactly reproducible from (suite, seed, placement).

use crate::sched::vtime::VirtualClock;
use crate::workload::AgentId;
use anyhow::{bail, Result};

/// Replica-placement policy selector (see module docs for semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Placement {
    /// Agent k → replica k mod N (balances agent counts).
    RoundRobin,
    /// Replica with the least outstanding predicted KV cost (fluid backlog).
    LeastLoaded,
    /// Replica minimizing the agent's hypothetical GPS-order finish tag —
    /// the cluster-fair extension of Justitia's virtual-time queuing.
    #[default]
    ClusterVtime,
}

impl Placement {
    /// Every placement policy, in report order.
    pub const ALL: [Placement; 3] =
        [Placement::RoundRobin, Placement::LeastLoaded, Placement::ClusterVtime];

    /// Parse a CLI/JSON policy name.
    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "round-robin" | "rr" => Ok(Placement::RoundRobin),
            "least-loaded" | "ll" => Ok(Placement::LeastLoaded),
            "cluster-vtime" | "vtime" => Ok(Placement::ClusterVtime),
            other => bail!("unknown placement '{other}' (round-robin|least-loaded|cluster-vtime)"),
        }
    }

    /// Canonical display name.
    pub fn name(&self) -> &'static str {
        match self {
            Placement::RoundRobin => "round-robin",
            Placement::LeastLoaded => "least-loaded",
            Placement::ClusterVtime => "cluster-vtime",
        }
    }
}

/// Per-replica placement bookkeeping owned by the dispatcher: a fluid
/// backlog of predicted cost (least-loaded) and a mirror virtual clock
/// (cluster-vtime). Both are updated on every placement regardless of the
/// active policy, so policies can be compared or switched without state
/// loss.
#[derive(Debug, Clone)]
pub(crate) struct ReplicaLoad {
    /// Outstanding predicted cost, drained at `drain_rate` per second.
    backlog: f64,
    /// Last time the backlog was decayed.
    last_t: f64,
    /// Cost units drained per second: M × rate_scale (one replica's nominal
    /// GPS service rate).
    drain_rate: f64,
    /// Mirror of the replica's fair-queuing virtual clock.
    pub(crate) vclock: VirtualClock,
}

impl ReplicaLoad {
    pub(crate) fn new(capacity_tokens: u64, rate_scale: f64) -> Self {
        ReplicaLoad {
            backlog: 0.0,
            last_t: 0.0,
            drain_rate: capacity_tokens as f64 * rate_scale,
            vclock: VirtualClock::new(capacity_tokens, rate_scale),
        }
    }

    /// Decay the fluid backlog to time `now` (monotone per replica).
    fn decay(&mut self, now: f64) {
        let now = now.max(self.last_t);
        self.backlog = (self.backlog - self.drain_rate * (now - self.last_t)).max(0.0);
        self.last_t = now;
    }

    /// Outstanding predicted cost at `now`.
    pub(crate) fn backlog_at(&mut self, now: f64) -> f64 {
        self.decay(now);
        self.backlog
    }

    /// Record that an agent with predicted `cost` was placed here at `now`.
    pub(crate) fn assign(&mut self, agent: AgentId, cost: f64, now: f64) {
        self.decay(now);
        self.backlog += cost;
        self.vclock.on_arrival(agent, cost, now.max(self.last_t));
    }
}

/// The placement decision engine: pure state machine, no engine access.
/// `nows[r]` is the time base of replica r (global arrival time for offline
/// trace replay; the replica's own engine clock for online serving).
#[derive(Debug, Clone)]
pub(crate) struct Placer {
    policy: Placement,
    rr_next: usize,
    pub(crate) loads: Vec<ReplicaLoad>,
}

impl Placer {
    pub(crate) fn new(policy: Placement, n: usize, capacity_tokens: u64, rate_scale: f64) -> Self {
        Placer {
            policy,
            rr_next: 0,
            loads: (0..n).map(|_| ReplicaLoad::new(capacity_tokens, rate_scale)).collect(),
        }
    }

    pub(crate) fn policy(&self) -> Placement {
        self.policy
    }

    /// Choose a replica for (`agent`, predicted `cost`) and update the
    /// per-replica bookkeeping. `live_estimates[r]`, when provided, replaces
    /// the mirror's GPS-finish estimate for cluster-vtime (used online where
    /// the live scheduler's virtual clock is exact).
    pub(crate) fn place(
        &mut self,
        agent: AgentId,
        cost: f64,
        nows: &[f64],
        live_estimates: Option<&[Option<f64>]>,
    ) -> usize {
        debug_assert_eq!(nows.len(), self.loads.len());
        let n = self.loads.len();
        let chosen = match self.policy {
            _ if n == 1 => 0,
            Placement::RoundRobin => {
                let r = self.rr_next % n;
                self.rr_next = (self.rr_next + 1) % n;
                r
            }
            Placement::LeastLoaded => argmin_f64((0..n).map(|r| self.loads[r].backlog_at(nows[r]))),
            Placement::ClusterVtime => argmin_f64((0..n).map(|r| {
                live_estimates
                    .and_then(|es| es[r])
                    .unwrap_or_else(|| self.loads[r].vclock.hypothetical_gps_finish(agent, cost, nows[r]))
            })),
        };
        self.loads[chosen].assign(agent, cost, nows[chosen]);
        chosen
    }
}

/// Index of the minimum value; ties break toward the lowest index.
fn argmin_f64(it: impl Iterator<Item = f64>) -> usize {
    let mut best = 0usize;
    let mut best_v = f64::INFINITY;
    for (i, v) in it.enumerate() {
        if v < best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for p in Placement::ALL {
            assert_eq!(Placement::by_name(p.name()).unwrap(), p);
        }
        assert_eq!(Placement::by_name("rr").unwrap(), Placement::RoundRobin);
        assert_eq!(Placement::by_name("vtime").unwrap(), Placement::ClusterVtime);
        assert!(Placement::by_name("random").is_err());
        assert_eq!(Placement::default(), Placement::ClusterVtime);
    }

    #[test]
    fn round_robin_cycles() {
        let mut p = Placer::new(Placement::RoundRobin, 3, 100, 1.0);
        let nows = [0.0, 0.0, 0.0];
        let seq: Vec<usize> = (0..6).map(|i| p.place(i, 10.0, &nows, None)).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_tracks_backlog() {
        let mut p = Placer::new(Placement::LeastLoaded, 2, 10, 1.0);
        // Heavy agent to replica 0 (tie → 0), light one must go to 1.
        assert_eq!(p.place(0, 1000.0, &[0.0, 0.0], None), 0);
        assert_eq!(p.place(1, 10.0, &[0.0, 0.0], None), 1);
        // Replica 1 drains (rate 10/s): by t=2 its backlog is 0, replica 0
        // still has ~980 → next goes to 1 again.
        assert_eq!(p.place(2, 10.0, &[2.0, 2.0], None), 1);
    }

    #[test]
    fn least_loaded_backlog_drains_to_zero() {
        let mut l = ReplicaLoad::new(10, 1.0);
        l.assign(0, 50.0, 0.0);
        assert!((l.backlog_at(1.0) - 40.0).abs() < 1e-9);
        assert_eq!(l.backlog_at(100.0), 0.0);
    }

    #[test]
    fn cluster_vtime_prefers_idle_replica() {
        let mut p = Placer::new(Placement::ClusterVtime, 2, 10, 1.0);
        // Saturate replica 0 with a big agent…
        assert_eq!(p.place(0, 500.0, &[0.0, 0.0], None), 0);
        // …the next agent's GPS finish is earlier on the empty replica 1.
        assert_eq!(p.place(1, 100.0, &[0.0, 0.0], None), 1);
        // A third agent (cost 200) at t=0: on replica 0 it shares with 500
        // the whole way (5/s → t=40); on replica 1 it shares with 100 until
        // t=20, then runs alone (t=30) → replica 1 wins.
        assert_eq!(p.place(2, 200.0, &[0.0, 0.0], None), 1);
    }

    #[test]
    fn cluster_vtime_honors_live_estimates() {
        let mut p = Placer::new(Placement::ClusterVtime, 2, 10, 1.0);
        // Live estimates invert the mirror-based choice.
        let r = p.place(0, 100.0, &[0.0, 0.0], Some(&[Some(9.0), Some(3.0)]));
        assert_eq!(r, 1);
    }

    #[test]
    fn single_replica_short_circuits() {
        for policy in Placement::ALL {
            let mut p = Placer::new(policy, 1, 100, 1.0);
            for i in 0..5 {
                assert_eq!(p.place(i, 100.0, &[i as f64], None), 0);
            }
        }
    }
}
