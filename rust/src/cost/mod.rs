//! Service-cost models (paper §4.1 and the Fig. 11 ablation).
//!
//! The paper's central modeling claim: LLM serving is *memory*-bound, so the
//! true service cost of an inference with prompt length `p` and decode length
//! `d` is its cumulative KV-cache occupation over its lifetime — the
//! *KV token-time*:
//!
//! ```text
//! c = sum_{i=1..d} (p + i) = p*d + d^2/2           (paper Eq. 1)
//! ```
//!
//! (quadratic in `d`), versus VTC's compute-centric `w_p*p + w_d*d` with
//! `w_p = 1, w_d = 2` (linear). An agent's cost is the sum over all its
//! inferences. The unit is token·iterations (paper footnote 1 normalizes KV
//! blocks to per-token units).

use crate::workload::{AgentId, AgentSpec, InferenceSpec, Suite};
use std::collections::HashMap;

/// A service-cost model mapping an inference's (p, d) to a scalar cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostModel {
    /// Paper Eq. (1): KV token-time, `p*d + d^2/2`.
    MemoryCentric,
    /// VTC (Sheng et al. 2024): `p + 2d`.
    ComputeCentric,
    /// Memory-centric with prefix dedup: when the engine's prefix cache is
    /// on, a shared page's token-time is charged *fractionally* across its
    /// sharers, so finish tags (and the GPS fluid reference) reflect the
    /// physical — deduplicated — occupancy. Per-inference this equals
    /// [`MemoryCentric`](CostModel::MemoryCentric); aggregation over tasks
    /// splits each shared-prefix term `L·d` by the sharer count (fluid
    /// approximation of the per-iteration page-refcount split the engine
    /// performs; see [`crate::prefix::PrefixCache::shared_charge`]).
    SharedMemoryCentric,
}

impl CostModel {
    /// Cost of a single inference.
    #[inline]
    pub fn inference_cost(&self, prompt: u32, decode: u32) -> f64 {
        let p = prompt as f64;
        let d = decode as f64;
        match self {
            // Exact discrete sum p*d + d(d+1)/2; the paper's p*d + d^2/2 is
            // its continuum approximation. Using the exact sum keeps
            // `remaining_inference_cost` consistent (depletes to exactly 0).
            CostModel::MemoryCentric | CostModel::SharedMemoryCentric => {
                p * d + d * (d + 1.0) / 2.0
            }
            CostModel::ComputeCentric => p + 2.0 * d,
        }
    }

    /// Cost of a whole inference spec.
    pub fn spec_cost(&self, spec: &InferenceSpec) -> f64 {
        self.inference_cost(spec.prompt_tokens, spec.decode_tokens)
    }

    /// Total cost of an agent = sum over all its inferences (paper §4.1).
    /// Under [`SharedMemoryCentric`](CostModel::SharedMemoryCentric) the
    /// shared-prefix token-time is split across the agent's *own* tasks in
    /// the same prefix group (intra-agent fan-out dedup); for suite-wide
    /// family dedup use [`shared_agent_costs`].
    pub fn agent_cost(&self, agent: &AgentSpec) -> f64 {
        match self {
            CostModel::SharedMemoryCentric => {
                let mut sharers: HashMap<u64, u32> = HashMap::new();
                for t in agent.tasks() {
                    if let Some(g) = t.prefix_group {
                        *sharers.entry(g.id).or_insert(0) += 1;
                    }
                }
                agent.tasks().map(|t| deduped_spec_cost(t, &sharers)).sum()
            }
            _ => agent.tasks().map(|s| self.spec_cost(s)).sum(),
        }
    }

    /// Remaining cost of a partially-served inference: served `g` of `d`
    /// decode tokens (prompt already processed). Memory-centric: the KV
    /// token-time still to be accumulated; compute-centric: remaining
    /// weighted tokens.
    pub fn remaining_inference_cost(&self, prompt: u32, decode: u32, generated: u32) -> f64 {
        let g = generated.min(decode);
        match self {
            CostModel::MemoryCentric | CostModel::SharedMemoryCentric => {
                // sum_{i=g+1..d} (p+i) = p(d-g) + (d(d+1) - g(g+1))/2
                let p = prompt as f64;
                let d = decode as f64;
                let g = g as f64;
                p * (d - g) + (d * (d + 1.0) - g * (g + 1.0)) / 2.0
            }
            CostModel::ComputeCentric => {
                if g == 0 {
                    prompt as f64 + 2.0 * decode as f64
                } else {
                    2.0 * (decode - g) as f64
                }
            }
        }
    }
}

/// Incremental cost accounting for a *running* inference, used by GPS/VTC
/// parity accounting in the engine: the memory-centric service delivered in
/// one iteration to a sequence currently holding `p + g` tokens of KV is
/// exactly its occupancy `p + g` (token·iterations per iteration).
#[inline]
pub fn kv_occupancy_tokens(prompt: u32, generated: u32) -> u64 {
    prompt as u64 + generated as u64
}

/// Critical-path cost of an agent's static task DAG: the heaviest
/// dependency chain, with each task weighted by its `model` cost. A lower
/// bound on the agent's serial work even at infinite parallelism — the
/// remaining-DAG signal [`crate::sched::AgentInfo::critical_path`] carries
/// to the schedulers. Spawned work is excluded (it is unknown at arrival,
/// which is exactly what the §4.2 correction loop compensates for).
pub fn critical_path_cost(model: CostModel, agent: &AgentSpec) -> f64 {
    let mut path = vec![0.0f64; agent.tasks.len()];
    let mut best = 0.0f64;
    for (i, t) in agent.tasks.iter().enumerate() {
        let up = t.deps.iter().map(|d| path[d.index as usize]).fold(0.0, f64::max);
        path[i] = up + model.spec_cost(t);
        best = best.max(path[i]);
    }
    best
}

/// End-to-end ground-truth agent cost *including* the deterministically
/// expanded spawned tasks ([`AgentSpec::expand_spawns`]). Identical to
/// [`CostModel::agent_cost`] for agents without a spawn rule, so every
/// pre-DAG path is unchanged. This is the honest oracle under dynamic
/// spawning: the work the engine will actually execute.
pub fn expanded_agent_cost(model: CostModel, agent: &AgentSpec) -> f64 {
    model.agent_cost(agent)
        + agent.expand_spawns().iter().map(|t| model.spec_cost(t)).sum::<f64>()
}

/// One inference's memory-centric cost with its shared-prefix token-time
/// divided by `sharers[group]` — the fluid dedup model. With one sharer it
/// reduces to Eq. (1) exactly: `(p−L)d + Ld/1 + d(d+1)/2 = pd + d(d+1)/2`.
fn deduped_spec_cost(spec: &InferenceSpec, sharers: &HashMap<u64, u32>) -> f64 {
    let p = spec.prompt_tokens as f64;
    let d = spec.decode_tokens as f64;
    let base = p * d + d * (d + 1.0) / 2.0;
    match spec.prefix_group {
        Some(g) => {
            let l = (g.tokens.min(spec.prompt_tokens)) as f64;
            let k = sharers.get(&g.id).copied().unwrap_or(1).max(1) as f64;
            base - l * d + l * d / k
        }
        None => base,
    }
}

/// Oracle (ground-truth) cost map for a run: plain per-agent `model` costs,
/// switching to the suite-wide deduplicated base ([`shared_agent_costs`])
/// when the prefix cache is on and the model is memory-centric — the single
/// gate every experiment path shares, so the scheduler's finish tags and
/// the GPS fluid yardstick can never disagree about the cost basis.
/// Without prefix annotations the deduplicated map equals the plain one
/// term for term, so the default path is unchanged.
pub fn oracle_costs(prefix_cache: bool, suite: &Suite, model: CostModel) -> HashMap<AgentId, f64> {
    if prefix_cache
        && matches!(model, CostModel::MemoryCentric | CostModel::SharedMemoryCentric)
    {
        let mut costs = shared_agent_costs(suite);
        // Spawned work carries no prefix annotations; it adds plainly.
        for a in &suite.agents {
            if a.spawn.is_some() {
                let extra: f64 = a.expand_spawns().iter().map(|t| model.spec_cost(t)).sum();
                *costs.get_mut(&a.id).expect("agent priced") += extra;
            }
        }
        costs
    } else {
        suite.agents.iter().map(|a| (a.id, expanded_agent_cost(model, a))).collect()
    }
}

/// Suite-wide deduplicated agent costs under
/// [`CostModel::SharedMemoryCentric`]: sharer counts are taken over *all*
/// inferences in the suite carrying the same prefix group (agent families),
/// not just within one agent. This is the cost the Justitia scheduler and
/// the GPS fluid reference should both see when the prefix cache is on, so
/// virtual-time finish tags stay truthful under dedup.
pub fn shared_agent_costs(suite: &Suite) -> HashMap<AgentId, f64> {
    let mut sharers: HashMap<u64, u32> = HashMap::new();
    for a in &suite.agents {
        for t in a.tasks() {
            if let Some(g) = t.prefix_group {
                *sharers.entry(g.id).or_insert(0) += 1;
            }
        }
    }
    suite
        .agents
        .iter()
        .map(|a| (a.id, a.tasks().map(|t| deduped_spec_cost(t, &sharers)).sum()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::test_support::inference;

    #[test]
    fn eq1_closed_form_matches_sum() {
        let m = CostModel::MemoryCentric;
        for (p, d) in [(10u32, 5u32), (0, 7), (100, 1), (37, 211)] {
            let direct: f64 = (1..=d).map(|i| (p + i) as f64).sum();
            let got = m.inference_cost(p, d);
            assert!((got - direct).abs() < 1e-9, "p={p} d={d} got={got} direct={direct}");
        }
    }

    #[test]
    fn quadratic_vs_linear_growth() {
        let m = CostModel::MemoryCentric;
        let c = CostModel::ComputeCentric;
        // Doubling d roughly quadruples the d^2 term in memory-centric cost
        // but only doubles compute-centric cost.
        let r_mem = m.inference_cost(0, 200) / m.inference_cost(0, 100);
        let r_cmp = c.inference_cost(0, 200) / c.inference_cost(0, 100);
        assert!(r_mem > 3.5, "{r_mem}");
        assert!((r_cmp - 2.0).abs() < 1e-9);
    }

    #[test]
    fn vtc_weights() {
        assert_eq!(CostModel::ComputeCentric.inference_cost(100, 50), 200.0);
    }

    #[test]
    fn remaining_cost_depletes_to_zero() {
        for model in [CostModel::MemoryCentric, CostModel::ComputeCentric] {
            let full = model.remaining_inference_cost(64, 32, 0);
            assert!(full > 0.0);
            let empty = model.remaining_inference_cost(64, 32, 32);
            assert!(empty.abs() < 1e-9, "{model:?} {empty}");
            // Monotone decreasing in g.
            let mut prev = f64::INFINITY;
            for g in 0..=32 {
                let r = model.remaining_inference_cost(64, 32, g);
                assert!(r <= prev + 1e-9);
                prev = r;
            }
        }
    }

    #[test]
    fn remaining_memory_cost_matches_discrete_sum() {
        let m = CostModel::MemoryCentric;
        let (p, d, g) = (20u32, 10u32, 4u32);
        let direct: f64 = ((g + 1)..=d).map(|i| (p + i) as f64).sum();
        assert!((m.remaining_inference_cost(p, d, g) - direct).abs() < 1e-9);
    }

    #[test]
    fn agent_cost_sums_stages() {
        let m = CostModel::MemoryCentric;
        let agent = crate::workload::test_support::agent_with_stages(vec![
            vec![inference(0, 0, 10, 4), inference(1, 0, 20, 6)],
            vec![inference(2, 1, 30, 8)],
        ]);
        let want = m.inference_cost(10, 4) + m.inference_cost(20, 6) + m.inference_cost(30, 8);
        assert!((m.agent_cost(&agent) - want).abs() < 1e-9);
    }

    #[test]
    fn occupancy() {
        assert_eq!(kv_occupancy_tokens(100, 7), 107);
    }

    #[test]
    fn critical_path_of_staged_agent_is_heaviest_chain() {
        let m = CostModel::MemoryCentric;
        let agent = crate::workload::test_support::agent_with_stages(vec![
            vec![inference(0, 0, 10, 4), inference(1, 0, 20, 6)],
            vec![inference(2, 1, 30, 8)],
        ]);
        // Heaviest stage-0 task (20,6) then the stage-1 task.
        let want = m.inference_cost(20, 6) + m.inference_cost(30, 8);
        assert!((critical_path_cost(m, &agent) - want).abs() < 1e-9);
        // A parallel single stage: critical path = max task, not the sum.
        let flat = crate::workload::test_support::simple_agent(0, 0.0, 5, 10, 4);
        assert!((critical_path_cost(m, &flat) - m.inference_cost(10, 4)).abs() < 1e-9);
    }

    #[test]
    fn critical_path_of_pipeline_equals_total() {
        let m = CostModel::MemoryCentric;
        let chain = crate::workload::test_support::dag_agent(
            0,
            0.0,
            vec![(10, 4, vec![]), (12, 5, vec![0]), (8, 3, vec![1])],
        );
        assert!((critical_path_cost(m, &chain) - m.agent_cost(&chain)).abs() < 1e-9);
    }

    #[test]
    fn expanded_cost_adds_spawned_work() {
        let m = CostModel::MemoryCentric;
        let mut a = crate::workload::test_support::simple_agent(0, 0.0, 2, 30, 10);
        assert_eq!(expanded_agent_cost(m, &a), m.agent_cost(&a));
        a.spawn = Some(crate::workload::SpawnSpec {
            prob: 1.0,
            branch: 2,
            max_depth: 1,
            seed: 11,
        });
        let spawned: f64 = a.expand_spawns().iter().map(|t| m.spec_cost(t)).sum();
        assert!(spawned > 0.0);
        assert!((expanded_agent_cost(m, &a) - (m.agent_cost(&a) + spawned)).abs() < 1e-9);
        // The oracle map prices the spawned work too.
        let suite = crate::workload::Suite::new(vec![a]);
        let costs = oracle_costs(false, &suite, m);
        assert!(
            (costs[&0] - expanded_agent_cost(m, &suite.agents[0])).abs() < 1e-9,
            "oracle must price spawned work"
        );
    }

    #[test]
    fn shared_model_matches_memory_centric_without_groups() {
        let m = CostModel::MemoryCentric;
        let s = CostModel::SharedMemoryCentric;
        let agent = crate::workload::test_support::agent_with_stages(vec![vec![
            inference(0, 0, 64, 16),
            inference(1, 0, 32, 8),
        ]]);
        assert_eq!(s.agent_cost(&agent), m.agent_cost(&agent));
        assert_eq!(s.inference_cost(64, 16), m.inference_cost(64, 16));
        assert_eq!(s.remaining_inference_cost(64, 16, 4), m.remaining_inference_cost(64, 16, 4));
    }

    #[test]
    fn shared_model_splits_prefix_across_intra_agent_sharers() {
        use crate::workload::PrefixGroup;
        let mut agent = crate::workload::test_support::agent_with_stages(vec![vec![
            inference(0, 0, 100, 10),
            inference(1, 0, 100, 10),
        ]]);
        let g = PrefixGroup { id: 1, tokens: 60 };
        for t in &mut agent.tasks {
            t.prefix_group = Some(g);
        }
        let full = CostModel::MemoryCentric.agent_cost(&agent);
        let shared = CostModel::SharedMemoryCentric.agent_cost(&agent);
        // Each task: 100·10 + 55 = 1055; dedup removes 60·10·(1 − 1/2) = 300
        // per task.
        assert!((full - 2.0 * 1055.0).abs() < 1e-9);
        assert!((shared - (full - 600.0)).abs() < 1e-9, "{shared} vs {full}");
    }

    #[test]
    fn suite_costs_dedup_across_agent_families() {
        use crate::workload::{PrefixGroup, Suite};
        let g = PrefixGroup { id: 4, tokens: 50 };
        let mut agents = Vec::new();
        for id in 0..2u32 {
            let mut a = crate::workload::test_support::agent_at(
                id,
                id as f64,
                vec![vec![inference(0, 0, 50, 10)]],
            );
            a.tasks[0].prefix_group = Some(g);
            agents.push(a);
        }
        let suite = Suite::new(agents);
        let costs = shared_agent_costs(&suite);
        // Suite-wide sharers = 2, so each agent's 50·10 prefix term halves;
        // intra-agent dedup alone would see k = 1 (no discount).
        let intra = CostModel::SharedMemoryCentric.agent_cost(&suite.agents[0]);
        let full = CostModel::MemoryCentric.agent_cost(&suite.agents[0]);
        assert_eq!(intra, full);
        assert!((costs[&0] - (full - 250.0)).abs() < 1e-9, "{}", costs[&0]);
        assert_eq!(costs.len(), 2);
        assert!((costs[&0] - costs[&1]).abs() < 1e-9);
    }
}
