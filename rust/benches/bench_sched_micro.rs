//! Scheduler micro-benchmarks — the §4.3 complexity claims: constant-cost
//! status refresh on arrival/completion, O(log N) next-agent selection —
//! plus engine-step and GPS-advance costs. This is the L3 hot path the
//! §Perf pass optimizes.

use justitia::config::{Config, Policy};
use justitia::cost::CostModel;
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::sched::{AgentInfo, Scheduler, TaskInfo};
use justitia::util::bench::{section, Bencher};
use justitia::workload::TaskId;
use std::hint::black_box;
use std::time::Duration;

fn main() {
    section("scheduler micro-ops");
    let mut b = Bencher::new().with_budget(Duration::from_secs(1));

    for n in [100u32, 1_000, 10_000] {
        // Pre-populate a Justitia scheduler with n waiting agents.
        let mut s = justitia::sched::justitia::Justitia::new(7344, 20.0);
        for i in 0..n {
            s.on_agent_arrival(&AgentInfo::new(i, i as f64 * 0.01, (i % 97) as f64 * 100.0), i as f64 * 0.01);
            Scheduler::push_task(
                &mut s,
                TaskInfo { id: TaskId { agent: i, index: 0 }, prompt_tokens: 100, predicted_decode: 50.0, seq: i as u64 },
                i as f64 * 0.01,
            );
        }
        b.bench(&format!("justitia.arrival+tag (N={n})"), |i| {
            let id = n + (i as u32 % 1000);
            s.on_agent_arrival(
                &AgentInfo::new(id, 1e6, 123.0),
                1e6 + i as f64,
            );
            black_box(s.tag(id));
        });
        b.bench(&format!("justitia.pop+push (N={n})"), |i| {
            if let Some(t) = justitia::sched::Scheduler::pop_next(&mut s, 1e6) {
                let _ = black_box(t);
                justitia::sched::Scheduler::push_task(&mut s, t, 1e6 + i as f64);
            }
        });
    }

    section("virtual clock (GPS fluid)");
    {
        let mut vc = justitia::sched::vtime::VirtualClock::new(7344, 20.0);
        let mut t = 0.0;
        let mut id = 0u32;
        b.bench("vclock.arrival+advance", |_| {
            t += 0.01;
            id += 1;
            black_box(vc.on_arrival(id, 5_000.0, t));
        });
    }

    section("engine step (simulator backend)");
    {
        let cfg = Config::default();
        let sched = justitia::sched::build(Policy::Justitia, cfg.backend.kv_tokens, 20.0);
        let mut engine = Engine::new(&cfg, sched, SimBackend::new(&cfg.backend));
        // Keep a rolling population of agents decoding.
        let mut next_id = 0u32;
        let model = CostModel::MemoryCentric;
        b.bench("engine.step (rolling ~16-seq batch)", |_| {
            if engine.running_len() < 12 {
                let a = justitia::workload::test_support::simple_agent(next_id, engine.now(), 2, 64, 64);
                let cost = model.agent_cost(&a);
                engine.submit(a, cost);
                next_id += 1;
            }
            black_box(engine.step());
        });
    }

    section("end-to-end suite runs (the Fig. 7 unit of work)");
    {
        let mut b2 = Bencher::new().with_budget(Duration::from_secs(5)).with_max_iters(20);
        for policy in [Policy::Vtc, Policy::Justitia] {
            b2.bench(&format!("run_suite 300 agents @3x ({})", policy.name()), |i| {
                let mut cfg = Config::default();
                cfg.workload = justitia::config::WorkloadConfig {
                    n_agents: 300,
                    seed: 42 + i,
                    ..Default::default()
                }
                .with_density(3.0);
                let suite = justitia::workload::trace::build_suite(&cfg.workload);
                let m = justitia::experiments::run_policy_oracle(&cfg, &suite, policy);
                black_box(m.avg_jct());
            });
        }
    }
}
