//! Fig. 3 — KV-block usage and JCT of two DocMerging agents under
//! instantaneous fair sharing (VTC) vs selective pampering (Justitia).
//!
//! Paper: avg JCT 210 s (fair sharing) → 166 s (pampering), no agent
//! delayed; M = 459 blocks on LLaMA2-7B / A100.

use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("Fig. 3: selective pampering vs instantaneous fair sharing");
    let mut out = ResultsFile::new("bench_fig3.txt");
    let r = justitia::experiments::fig3(42);
    out.line(format!("{:<10} {:>10} {:>10} {:>10}", "policy", "JCT(a0)", "JCT(a1)", "avg"));
    let mut avgs = Vec::new();
    for (name, jcts, avg) in &r.rows {
        out.line(format!("{:<10} {:>9.1}s {:>9.1}s {:>9.1}s", name, jcts[0], jcts[1], avg));
        avgs.push(*avg);
    }
    out.line(format!(
        "pampering reduces avg JCT by {:.1}% (paper: 21% — 210 s → 166 s)",
        (1.0 - avgs[1] / avgs[0]) * 100.0
    ));
    // Occupancy timelines (the Fig. 3 bar charts): quartile-bucketed.
    for (name, tl) in &r.timelines {
        let span = tl.last().map(|(t, _)| *t).unwrap_or(0.0);
        let mut buckets = vec![(0u64, 0usize); 8];
        for (t, v) in tl {
            let i = ((t / span * 8.0) as usize).min(7);
            buckets[i].0 += v;
            buckets[i].1 += 1;
        }
        let profile: Vec<u64> =
            buckets.iter().map(|(s, n)| if *n > 0 { s / *n as u64 } else { 0 }).collect();
        out.line(format!("{name:<10} occupancy/8th-of-run (tokens): {profile:?}"));
    }
}
