// Fixture: knob defaults that drift from the committed manifest.
pub struct Config {
    pub fairness: bool,
    pub max_batch: u32,
    pub new_feature: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            // Mismatch: manifest pins `false`.
            fairness: true,
            max_batch: 64,
            // Unregistered: not present in the manifest at all.
            new_feature: false,
        }
    }
}
