//! Arrival traces and suite building (§5.1 Workloads; substitution T2).
//!
//! The paper replays the Mooncake production trace's request arrival times,
//! stretched to 6/9/18-minute submission windows for 3×/2×/1× density. That
//! trace is not available offline; we generate a bursty Gamma-renewal arrival
//! process (shape k < 1 ⇒ CV > 1, matching the burstiness production LLM
//! traces exhibit) normalized to the same windows, and sample classes with
//! the 72/26/2 small/medium/large mix.
//!
//! Trace files carry two task encodings:
//!
//! * the **legacy staged form** — `"stages": [[{p, d, ...}], ...]` — written
//!   whenever an agent's DAG is an exact barrier sequence (every pre-DAG
//!   trace is, so old files round-trip bit-identically), and
//! * the **DAG form** — `"tasks": [{p, d, stage, deps: [indices], ...}]`
//!   plus an optional `"spawn"` rule — for everything else.
//!
//! The reader accepts both.

use crate::util::json::{obj, Json};
use crate::util::rng::Rng;
use crate::workload::classes::SizeBucket;
use crate::workload::generator::{DagShape, Generator};
use crate::workload::{AgentClass, AgentSpec, InferenceSpec, SpawnSpec, Suite, TaskId};
use anyhow::{Context, Result};
use std::path::Path;

/// Gamma-renewal arrival process: inter-arrival ~ Gamma(shape, scale). The
/// shape < 1 gives coefficient of variation 1/sqrt(shape) > 1 ("bursty").
pub const ARRIVAL_GAMMA_SHAPE: f64 = 0.5; // CV ≈ 1.41, production-like

/// Generate `n` arrival offsets inside `[0, window_secs]`, sorted.
pub fn arrivals(rng: &mut Rng, n: usize, window_secs: f64) -> Vec<f64> {
    if n == 0 {
        return Vec::new();
    }
    // Draw n bursty gaps, then renormalize the cumulative sum to the window
    // (exactly what "replay a trace stretched to the window" does).
    let gaps: Vec<f64> = (0..n).map(|_| rng.gamma(ARRIVAL_GAMMA_SHAPE, 1.0)).collect();
    let mut cum: Vec<f64> = Vec::with_capacity(n);
    let mut s = 0.0;
    for g in &gaps {
        s += g;
        cum.push(s);
    }
    let total = s.max(1e-9);
    cum.iter().map(|c| c / total * window_secs).collect()
}

/// Sample an agent class with the paper's 72/26/2 size mix, uniform within
/// the bucket.
pub fn sample_class(rng: &mut Rng, class_mix: &[f64; 3]) -> AgentClass {
    let bucket = match rng.categorical(class_mix) {
        0 => SizeBucket::Small,
        1 => SizeBucket::Medium,
        _ => SizeBucket::Large,
    };
    let classes = AgentClass::in_bucket(bucket);
    *rng.choose(&classes)
}

/// Build the full §5.1 workload suite. When the config's shared-prefix knobs
/// are set (`prefix_fanout ≥ 2` and `prefix_tokens > 0`), the suite is
/// additionally partitioned into *agent families*: consecutive agents (in
/// arrival order) are grouped `prefix_fanout` at a time and every inference
/// of a family is annotated with the same [`PrefixGroup`](crate::workload::PrefixGroup)
/// — modeling fleets of agents re-submitting the same long system prompt +
/// context. The annotation is inert unless the engine's prefix cache is on,
/// so the default (0/0) suite is bit-identical to the unannotated one.
///
/// With `cfg.dag` set, agents are DAG-shaped instead (shapes sampled
/// uniformly over [`DagShape::ALL`], spawn knobs from the config); the
/// default `dag: false` path is untouched and bit-identical to pre-DAG
/// builds.
pub fn build_suite(cfg: &crate::config::WorkloadConfig) -> Suite {
    build_suite_shaped(cfg, None)
}

/// Build a suite with every agent forced to one DAG shape (the `dag_agents`
/// experiment sweeps shapes one at a time).
pub fn build_dag_suite(cfg: &crate::config::WorkloadConfig, shape: DagShape) -> Suite {
    build_suite_shaped(cfg, Some(shape))
}

/// [`build_suite`] with every agent forced to one DAG shape (the
/// `dag_agents` experiment sweeps shapes one at a time). `None` with
/// `cfg.dag` samples shapes uniformly; `None` without `cfg.dag` is the
/// plain staged suite.
pub fn build_suite_shaped(
    cfg: &crate::config::WorkloadConfig,
    shape: Option<DagShape>,
) -> Suite {
    build_suite_inner(cfg, shape, false)
}

/// [`build_suite`] with every agent's `input_text` dropped after generation.
///
/// At 1M+ agents the synthesized prompt text dominates suite memory by an
/// order of magnitude and nothing in a cost-oracle cluster run reads it
/// (predictor work passes `with_text` traces instead). Dropping it is
/// RNG-safe — `synthesize_input` is the *last* draw from each agent's forked
/// stream — so the lean suite is identical to [`build_suite`]'s except for
/// the empty `input_text` (asserted in tests).
pub fn build_suite_lean(cfg: &crate::config::WorkloadConfig) -> Suite {
    build_suite_inner(cfg, None, true)
}

fn build_suite_inner(
    cfg: &crate::config::WorkloadConfig,
    shape: Option<DagShape>,
    lean: bool,
) -> Suite {
    let mut rng = Rng::with_stream(cfg.seed, 0x7ace);
    // Shapes draw from their own stream: enabling DAG mode must not shift
    // the shared stream's class draws, so same-seed suites keep identical
    // classes and arrivals across dag on/off (asserted in tests).
    let mut shape_rng = Rng::with_stream(cfg.seed, 0xd5a9);
    let mut gen = Generator::new(cfg.seed ^ 0xabcd_ef01);
    let times = arrivals(&mut rng, cfg.n_agents, cfg.window_secs);
    let agents = times
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let class = sample_class(&mut rng, &cfg.class_mix);
            let mut a = if cfg.dag || shape.is_some() {
                let s = shape.unwrap_or_else(|| *shape_rng.choose(&DagShape::ALL));
                gen.dag_agent(class, s, i as u32, t, cfg.spawn_prob, cfg.branch)
            } else {
                gen.agent(class, i as u32, t)
            };
            if lean {
                a.input_text = String::new();
            }
            a
        })
        .collect();
    let mut suite = Suite::new(agents);
    if cfg.prefix_fanout >= 2 && cfg.prefix_tokens > 0 {
        annotate_families(&mut suite, cfg.prefix_fanout, cfg.prefix_tokens, cfg.seed);
    }
    suite
}

/// Stamp shared-prefix family annotations onto an existing suite: agents
/// `[k·fanout, (k+1)·fanout)` in arrival order form family `k`, all sharing
/// one `prefix_tokens`-long prompt prefix (clamped per task to its own
/// prompt length by the cache).
pub fn annotate_families(suite: &mut Suite, fanout: usize, prefix_tokens: u32, seed: u64) {
    for (i, a) in suite.agents.iter_mut().enumerate() {
        // Family ids are salted with the seed so two suites never alias.
        let group = crate::workload::PrefixGroup {
            id: seed.rotate_left(24) ^ ((i / fanout) as u64),
            tokens: prefix_tokens,
        };
        for t in &mut a.tasks {
            t.prefix_group = Some(group);
        }
    }
}

/// Whether the legacy reader would reassign exactly the kinds this staged
/// agent carries (it keys kinds off the class template by stage index, so a
/// DAG-built pipeline longer than the template, or hand-built kinds, would
/// be mangled by a stages round trip).
fn legacy_kinds_match(a: &AgentSpec, stages: &[Vec<&InferenceSpec>]) -> bool {
    let template = a.class.template();
    stages.iter().enumerate().all(|(s, st)| {
        let kind = template.stages.get(s).map(|t| t.kind).unwrap_or("replay");
        st.iter().all(|t| t.kind == kind)
    })
}

/// Serialize one task's scalar fields (shared by both encodings).
fn task_fields(t: &InferenceSpec) -> Json {
    let mut o = obj([
        ("p", t.prompt_tokens.into()),
        ("d", t.decode_tokens.into()),
        ("kind", t.kind.into()),
    ]);
    if let Some(g) = t.prefix_group {
        if let Json::Obj(map) = &mut o {
            // Hex string: u64 ids survive the
            // f64-backed number representation.
            map.insert("pg".into(), Json::Str(format!("{:x}", g.id)));
            map.insert("pt".into(), Json::Num(g.tokens as f64));
        }
    }
    o
}

/// Serialize a suite to JSON (tasks only — input text elided by default to
/// keep trace files small; pass `with_text` to keep it for predictor work).
/// Staged agents use the legacy `"stages"` encoding (pre-DAG traces
/// round-trip bit-identically); general DAGs use the `"tasks"` encoding.
pub fn suite_to_json(suite: &Suite, with_text: bool) -> Json {
    let agents: Vec<Json> = suite
        .agents
        .iter()
        .map(|a| {
            let mut fields = vec![
                ("class".to_string(), Json::Str(a.class.short_name().into())),
                ("arrival".to_string(), Json::Num(a.arrival)),
            ];
            // Legacy encoding only for agents the legacy reader rebuilds
            // faithfully: barrier-form DAGs without a spawn rule whose kinds
            // match the template by stage index (every pre-DAG trace
            // qualifies). Everything else — spawning agents, DAG-built
            // pipelines longer than the template, hand-built kinds — keeps
            // the explicit task encoding so per-task kinds survive.
            match a
                .as_stages()
                .filter(|st| a.spawn.is_none() && legacy_kinds_match(a, st))
            {
                Some(stages) => {
                    let stages: Vec<Json> = stages
                        .iter()
                        .map(|st| Json::Arr(st.iter().map(|&t| task_fields(t)).collect()))
                        .collect();
                    fields.push(("stages".to_string(), Json::Arr(stages)));
                }
                None => {
                    let tasks: Vec<Json> = a
                        .tasks
                        .iter()
                        .map(|t| {
                            let mut o = task_fields(t);
                            if let Json::Obj(map) = &mut o {
                                map.insert("stage".into(), Json::Num(t.stage as f64));
                                map.insert(
                                    "deps".into(),
                                    Json::Arr(
                                        t.deps
                                            .iter()
                                            .map(|d| Json::Num(d.index as f64))
                                            .collect(),
                                    ),
                                );
                            }
                            o
                        })
                        .collect();
                    fields.push(("tasks".to_string(), Json::Arr(tasks)));
                }
            }
            if let Some(sp) = &a.spawn {
                fields.push((
                    "spawn".to_string(),
                    obj([
                        ("prob", Json::Num(sp.prob)),
                        ("branch", Json::Num(sp.branch as f64)),
                        ("max_depth", Json::Num(sp.max_depth as f64)),
                        ("seed", Json::Str(format!("{:x}", sp.seed))),
                    ]),
                ));
            }
            if with_text {
                fields.push(("input".to_string(), Json::Str(a.input_text.clone())));
            }
            Json::Obj(fields.into_iter().collect())
        })
        .collect();
    obj([("agents", Json::Arr(agents))])
}

/// Intern a task-kind string against the class template's stage kinds (plus
/// the built-in dynamic-task labels), falling back to "replay".
fn intern_kind(class: AgentClass, kind: Option<&str>) -> &'static str {
    let Some(kind) = kind else { return "replay" };
    for st in class.template().stages {
        if st.kind == kind {
            return st.kind;
        }
    }
    match kind {
        "spawned" => "spawned",
        "test" => "test",
        "http" => "http",
        _ => "replay",
    }
}

fn parse_prefix_group(i: usize, t: &Json) -> Result<Option<crate::workload::PrefixGroup>> {
    match (t.get("pg").as_str(), t.get("pt").as_u64()) {
        (Some(hex), Some(tokens)) => Ok(Some(crate::workload::PrefixGroup {
            id: u64::from_str_radix(hex, 16).context("pg")?,
            tokens: tokens as u32,
        })),
        (None, None) => Ok(None),
        _ => anyhow::bail!(
            "agent {i}: task has a partial prefix-group annotation \
             (both \"pg\" and \"pt\" are required)"
        ),
    }
}

fn parse_spawn(a: &Json) -> Result<Option<SpawnSpec>> {
    let s = a.get("spawn");
    if s.as_obj().is_none() {
        return Ok(None);
    }
    Ok(Some(SpawnSpec {
        prob: s.get("prob").as_f64().context("spawn.prob")?,
        branch: s.get("branch").as_u64().context("spawn.branch")? as u32,
        max_depth: s.get("max_depth").as_u64().context("spawn.max_depth")? as u32,
        seed: u64::from_str_radix(s.get("seed").as_str().context("spawn.seed")?, 16)
            .context("spawn.seed")?,
    }))
}

/// Parse a suite back from JSON. Accepts both the legacy `"stages"` encoding
/// (kind strings are interned to the class template's stage kinds when they
/// match, else "replay") and the DAG `"tasks"` encoding.
pub fn suite_from_json(v: &Json) -> Result<Suite> {
    let mut agents = Vec::new();
    for (i, a) in v.get("agents").as_arr().context("agents")?.iter().enumerate() {
        let class = AgentClass::by_short_name(a.get("class").as_str().context("class")?)
            .context("unknown class")?;
        let arrival = a.get("arrival").as_f64().context("arrival")?;
        let template = class.template();
        let input_text = a.get("input").as_str().unwrap_or("").to_string();
        let spawn = parse_spawn(a)?;

        let mut spec = if let Some(stages_json) = a.get("stages").as_arr() {
            // Legacy staged encoding: rebuild the barrier DAG.
            let mut stages = Vec::new();
            for (s, st) in stages_json.iter().enumerate() {
                let kind = template.stages.get(s).map(|t| t.kind).unwrap_or("replay");
                let mut tasks = Vec::new();
                for t in st.as_arr().context("stage")? {
                    tasks.push(InferenceSpec {
                        id: TaskId { agent: i as u32, index: 0 }, // from_stages re-stamps
                        stage: s as u32,
                        deps: Vec::new(),
                        prompt_tokens: t.get("p").as_u64().context("p")? as u32,
                        decode_tokens: t.get("d").as_u64().context("d")? as u32,
                        kind,
                        prefix_group: parse_prefix_group(i, t)?,
                    });
                }
                stages.push(tasks);
            }
            AgentSpec::from_stages(i as u32, class, arrival, stages, input_text)
        } else {
            // DAG encoding: explicit per-task stage labels and dependencies.
            let mut tasks = Vec::new();
            for (j, t) in a.get("tasks").as_arr().context("stages or tasks")?.iter().enumerate()
            {
                let deps: Vec<TaskId> = match t.get("deps").as_arr() {
                    Some(ds) => ds
                        .iter()
                        .map(|d| -> Result<TaskId> {
                            let di = d.as_u64().context("dep index")? as u32;
                            anyhow::ensure!(
                                (di as usize) < j,
                                "agent {i}: task {j} depends on non-earlier task {di}"
                            );
                            Ok(TaskId { agent: i as u32, index: di })
                        })
                        .collect::<Result<_>>()?,
                    None => Vec::new(),
                };
                tasks.push(InferenceSpec {
                    id: TaskId { agent: i as u32, index: j as u32 },
                    stage: t.get("stage").as_u64().unwrap_or(0) as u32,
                    deps,
                    prompt_tokens: t.get("p").as_u64().context("p")? as u32,
                    decode_tokens: t.get("d").as_u64().context("d")? as u32,
                    kind: intern_kind(class, t.get("kind").as_str()),
                    prefix_group: parse_prefix_group(i, t)?,
                });
            }
            AgentSpec { id: i as u32, class, arrival, tasks, spawn: None, input_text }
        };
        spec.spawn = spawn;
        agents.push(spec);
    }
    Ok(Suite::new(agents))
}

/// Write a suite trace file.
pub fn save_suite(suite: &Suite, path: &Path, with_text: bool) -> Result<()> {
    std::fs::write(path, suite_to_json(suite, with_text).pretty())
        .with_context(|| format!("write {}", path.display()))
}

/// Load a suite trace file.
pub fn load_suite(path: &Path) -> Result<Suite> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
    let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    suite_from_json(&v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    #[test]
    fn arrivals_sorted_within_window() {
        let mut rng = Rng::new(3);
        let ts = arrivals(&mut rng, 200, 360.0);
        assert_eq!(ts.len(), 200);
        for w in ts.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!(*ts.last().unwrap() <= 360.0 + 1e-9);
        assert!(ts[0] >= 0.0);
    }

    #[test]
    fn arrivals_are_bursty() {
        // CV of inter-arrival gaps should exceed 1 (Gamma shape 0.5 ⇒ ~1.4).
        let mut rng = Rng::new(5);
        let ts = arrivals(&mut rng, 2000, 1000.0);
        let gaps: Vec<f64> = ts.windows(2).map(|w| w[1] - w[0]).collect();
        let m = crate::util::stats::mean(&gaps);
        let s = crate::util::stats::std_dev(&gaps);
        assert!(s / m > 1.15, "cv={}", s / m);
    }

    #[test]
    fn class_mix_matches_72_26_2() {
        let mut rng = Rng::new(7);
        let mix = [0.72, 0.26, 0.02];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            let c = sample_class(&mut rng, &mix);
            counts[match c.size_bucket() {
                SizeBucket::Small => 0,
                SizeBucket::Medium => 1,
                SizeBucket::Large => 2,
            }] += 1;
        }
        assert!((counts[0] as f64 / 2e4 - 0.72).abs() < 0.02);
        assert!((counts[1] as f64 / 2e4 - 0.26).abs() < 0.02);
        assert!((counts[2] as f64 / 2e4 - 0.02).abs() < 0.01);
    }

    #[test]
    fn build_suite_deterministic() {
        let cfg = WorkloadConfig { n_agents: 40, window_secs: 120.0, ..Default::default() };
        let s1 = build_suite(&cfg);
        let s2 = build_suite(&cfg);
        assert_eq!(s1.agents, s2.agents);
        assert_eq!(s1.len(), 40);
        let cfg2 = WorkloadConfig { seed: 43, ..cfg };
        let s3 = build_suite(&cfg2);
        assert_ne!(s1.agents, s3.agents);
    }

    #[test]
    fn lean_suite_matches_full_except_text() {
        let cfg = WorkloadConfig { n_agents: 30, window_secs: 90.0, ..Default::default() };
        let full = build_suite(&cfg);
        let lean = build_suite_lean(&cfg);
        assert_eq!(full.len(), lean.len());
        for (a, b) in full.agents.iter().zip(lean.agents.iter()) {
            assert!(b.input_text.is_empty(), "lean suite must drop input text");
            assert!(!a.input_text.is_empty(), "full suite keeps input text");
            let mut stripped = a.clone();
            stripped.input_text = String::new();
            assert_eq!(&stripped, b, "lean suite differs beyond input_text");
        }
    }

    #[test]
    fn dag_suite_is_deterministic_and_gated() {
        let plain = WorkloadConfig { n_agents: 20, window_secs: 60.0, ..Default::default() };
        let dag = WorkloadConfig { dag: true, spawn_prob: 0.3, branch: 3, ..plain.clone() };
        let d1 = build_suite(&dag);
        let d2 = build_suite(&dag);
        assert_eq!(d1.agents, d2.agents);
        // Arrivals match the plain suite (same arrival stream)…
        let p = build_suite(&plain);
        for (a, b) in d1.agents.iter().zip(p.agents.iter()) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.class, b.class);
        }
        // …but the DAG suite carries spawn rules and non-staged shapes.
        assert!(d1.agents.iter().all(|a| a.spawn.is_some()));
        assert!(d1.agents.iter().any(|a| a.as_stages().is_none()));
        assert!(p.agents.iter().all(|a| a.spawn.is_none()));
        // Forcing a single shape is deterministic too and skips mixing.
        let t1 = build_suite_shaped(&dag, Some(DagShape::Pipeline));
        let t2 = build_suite_shaped(&dag, Some(DagShape::Pipeline));
        assert_eq!(t1.agents, t2.agents);
        assert!(t1.agents.iter().all(|a| a.depth() == a.n_tasks()));
    }

    #[test]
    fn json_roundtrip() {
        let cfg = WorkloadConfig {
            n_agents: 12,
            window_secs: 60.0,
            prefix_fanout: 3,
            prefix_tokens: 256,
            ..Default::default()
        };
        let suite = build_suite(&cfg);
        let j = suite_to_json(&suite, true);
        let back = suite_from_json(&j).unwrap();
        assert_eq!(back.len(), suite.len());
        for (a, b) in suite.agents.iter().zip(back.agents.iter()) {
            assert_eq!(a.class, b.class);
            assert!((a.arrival - b.arrival).abs() < 1e-9);
            assert_eq!(a.n_tasks(), b.n_tasks());
            assert_eq!(a.input_text, b.input_text);
            for (x, y) in a.tasks().zip(b.tasks()) {
                assert_eq!((x.prompt_tokens, x.decode_tokens), (y.prompt_tokens, y.decode_tokens));
                assert_eq!(x.prefix_group, y.prefix_group);
            }
        }
    }

    #[test]
    fn legacy_staged_json_roundtrips_bit_identically() {
        // ISSUE 3 regression: a staged suite serialized, parsed, and
        // re-serialized must produce byte-identical JSON — the legacy
        // "stages" encoding survives the DAG representation unchanged.
        let cfg = WorkloadConfig {
            n_agents: 15,
            window_secs: 90.0,
            prefix_fanout: 3,
            prefix_tokens: 128,
            ..Default::default()
        };
        let suite = build_suite(&cfg);
        assert!(suite.agents.iter().all(|a| a.as_stages().is_some()));
        let first = suite_to_json(&suite, true).pretty();
        let reparsed = suite_from_json(&Json::parse(&first).unwrap()).unwrap();
        let second = suite_to_json(&reparsed, true).pretty();
        assert_eq!(first, second, "legacy stages JSON must round-trip bit-identically");
        assert_eq!(suite.agents, reparsed.agents, "parsed specs must match the originals");
    }

    #[test]
    fn dag_json_roundtrips_tasks_and_spawn() {
        let cfg = WorkloadConfig {
            n_agents: 9,
            window_secs: 60.0,
            dag: true,
            spawn_prob: 0.5,
            branch: 2,
            ..Default::default()
        };
        let suite = build_suite(&cfg);
        let j = suite_to_json(&suite, false).pretty();
        let back = suite_from_json(&Json::parse(&j).unwrap()).unwrap();
        for (a, b) in suite.agents.iter().zip(back.agents.iter()) {
            assert_eq!(a.spawn, b.spawn, "spawn rules must survive the trace");
            assert_eq!(a.tasks.len(), b.tasks.len());
            for (x, y) in a.tasks().zip(b.tasks()) {
                assert_eq!(x.deps, y.deps, "dependencies must survive the trace");
                assert_eq!(x.stage, y.stage);
                assert_eq!((x.prompt_tokens, x.decode_tokens), (y.prompt_tokens, y.decode_tokens));
            }
            // Spawn expansion — the runtime-visible task set — agrees too.
            assert_eq!(a.expand_spawns(), b.expand_spawns());
        }
        // Second round trip is textually stable.
        assert_eq!(j, suite_to_json(&back, false).pretty());
    }

    #[test]
    fn spawn_free_dag_pipelines_round_trip_kinds_faithfully() {
        // A dag suite with spawn_prob 0: pipeline agents are barrier-form
        // but longer than their class template, so the legacy encoding
        // would mangle their kinds — the writer must pick the task
        // encoding and the round trip must be exact.
        let cfg = WorkloadConfig {
            n_agents: 10,
            window_secs: 40.0,
            dag: true,
            spawn_prob: 0.0,
            ..Default::default()
        };
        let suite = build_suite_shaped(&cfg, Some(DagShape::Pipeline));
        let j = suite_to_json(&suite, false).pretty();
        let back = suite_from_json(&Json::parse(&j).unwrap()).unwrap();
        for (a, b) in suite.agents.iter().zip(back.agents.iter()) {
            assert_eq!(a.tasks, b.tasks, "pipeline tasks (incl. kinds) must survive");
        }
        assert_eq!(j, suite_to_json(&back, false).pretty());
    }

    #[test]
    fn shared_prefix_families_group_consecutive_agents() {
        let cfg = WorkloadConfig {
            n_agents: 10,
            window_secs: 60.0,
            prefix_fanout: 4,
            prefix_tokens: 512,
            ..Default::default()
        };
        let suite = build_suite(&cfg);
        let gid = |i: usize| suite.agents[i].prefix_group_id().unwrap();
        // Agents 0..4 share one family, 4..8 another, 8..10 the tail family.
        assert_eq!(gid(0), gid(3));
        assert_ne!(gid(3), gid(4));
        assert_eq!(gid(4), gid(7));
        assert_eq!(gid(8), gid(9));
        // Every task carries the annotation with the configured length.
        for a in &suite.agents {
            for t in a.tasks() {
                assert_eq!(t.prefix_group.unwrap().tokens, 512);
            }
        }
        // Default knobs leave the suite unannotated (and otherwise equal).
        let plain = build_suite(&WorkloadConfig {
            n_agents: 10,
            window_secs: 60.0,
            ..Default::default()
        });
        assert!(plain.agents.iter().all(|a| a.prefix_group_id().is_none()));
        for (a, b) in suite.agents.iter().zip(plain.agents.iter()) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.n_tasks(), b.n_tasks());
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("justitia-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("suite.json");
        let cfg = WorkloadConfig { n_agents: 5, window_secs: 30.0, ..Default::default() };
        let suite = build_suite(&cfg);
        save_suite(&suite, &path, false).unwrap();
        let back = load_suite(&path).unwrap();
        assert_eq!(back.len(), 5);
    }
}
