//! Reader/writer for the `.jtt` tensor container ("Justitia tensors"), the
//! interchange format for model weights between `python/compile/aot.py`
//! (writer) and `rust/src/runtime` (reader). A safetensors-like layout:
//!
//! ```text
//! bytes 0..4   magic b"JTT1"
//! bytes 4..8   u32 LE header length H
//! bytes 8..8+H JSON header: {"tensors": [{"name", "dtype", "shape", "offset", "nbytes"}, ...]}
//! bytes 8+H..  raw tensor data, little-endian, at the stated offsets
//! ```
//!
//! Only f32 and i32 dtypes are needed by the model runner.

use crate::util::json::{obj, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// One named tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Tensor name (manifest key).
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Dimensions.
    pub shape: Vec<usize>,
    /// f32 payload (empty for i32 tensors).
    pub data_f32: Vec<f32>,
    /// i32 payload (empty for f32 tensors).
    pub data_i32: Vec<i32>,
}

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    fn as_str(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

impl Tensor {
    /// Build an f32 tensor.
    pub fn f32(name: impl Into<String>, shape: Vec<usize>, data: Vec<f32>) -> Self {
        let t = Tensor { name: name.into(), dtype: DType::F32, shape, data_f32: data, data_i32: Vec::new() };
        debug_assert_eq!(t.numel(), t.data_f32.len());
        t
    }

    /// Build an i32 tensor.
    pub fn i32(name: impl Into<String>, shape: Vec<usize>, data: Vec<i32>) -> Self {
        let t = Tensor { name: name.into(), dtype: DType::I32, shape, data_f32: Vec::new(), data_i32: data };
        debug_assert_eq!(t.numel(), t.data_i32.len());
        t
    }

    /// Element count (product of dims).
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn nbytes(&self) -> usize {
        self.numel() * 4
    }
}

/// Write tensors to a `.jtt` file.
pub fn write_jtt(path: &Path, tensors: &[Tensor]) -> Result<()> {
    let mut entries = Vec::new();
    let mut offset = 0usize;
    for t in tensors {
        entries.push(obj([
            ("name", t.name.as_str().into()),
            ("dtype", t.dtype.as_str().into()),
            ("shape", Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect())),
            ("offset", offset.into()),
            ("nbytes", t.nbytes().into()),
        ]));
        offset += t.nbytes();
    }
    let header = obj([("tensors", Json::Arr(entries))]).dump();
    let mut f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    f.write_all(b"JTT1")?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for t in tensors {
        match t.dtype {
            DType::F32 => {
                for x in &t.data_f32 {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            DType::I32 => {
                for x in &t.data_i32 {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

/// Read all tensors from a `.jtt` file, keyed by name.
pub fn read_jtt(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"JTT1" {
        bail!("{}: bad magic {magic:?}", path.display());
    }
    let mut len_bytes = [0u8; 4];
    f.read_exact(&mut len_bytes)?;
    let hlen = u32::from_le_bytes(len_bytes) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf).context("header utf8")?)
        .map_err(|e| anyhow::anyhow!("header json: {e}"))?;
    let mut data = Vec::new();
    f.read_to_end(&mut data)?;

    let mut out = BTreeMap::new();
    for e in header.get("tensors").as_arr().context("tensors array")? {
        let name = e.get("name").as_str().context("name")?.to_string();
        let dtype = DType::from_str(e.get("dtype").as_str().context("dtype")?)?;
        let shape: Vec<usize> = e
            .get("shape")
            .as_arr()
            .context("shape")?
            .iter()
            .map(|d| d.as_u64().map(|x| x as usize).context("shape dim"))
            .collect::<Result<_>>()?;
        let offset = e.get("offset").as_u64().context("offset")? as usize;
        let nbytes = e.get("nbytes").as_u64().context("nbytes")? as usize;
        if offset + nbytes > data.len() {
            bail!("tensor {name} out of bounds ({offset}+{nbytes} > {})", data.len());
        }
        let raw = &data[offset..offset + nbytes];
        let t = match dtype {
            DType::F32 => Tensor::f32(
                name.clone(),
                shape,
                raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
            ),
            DType::I32 => Tensor::i32(
                name.clone(),
                shape,
                raw.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
            ),
        };
        if t.numel() * 4 != nbytes {
            bail!("tensor {name}: shape/nbytes mismatch");
        }
        out.insert(name, t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("justitia-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let path = tmp("rt.jtt");
        let tensors = vec![
            Tensor::f32("w1", vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.0]),
            Tensor::i32("ids", vec![4], vec![1, -2, 3, 4]),
            Tensor::f32("scalar", vec![], vec![42.0]),
        ];
        write_jtt(&path, &tensors).unwrap();
        let back = read_jtt(&path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back["w1"], tensors[0]);
        assert_eq!(back["ids"], tensors[1]);
        assert_eq!(back["scalar"], tensors[2]);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad.jtt");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_jtt(&path).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let path = tmp("trunc.jtt");
        let tensors = vec![Tensor::f32("w", vec![8], vec![0.0; 8])];
        write_jtt(&path, &tensors).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        assert!(read_jtt(&path).is_err());
    }

    #[test]
    fn empty_file_of_tensors() {
        let path = tmp("empty.jtt");
        write_jtt(&path, &[]).unwrap();
        assert!(read_jtt(&path).unwrap().is_empty());
    }
}
