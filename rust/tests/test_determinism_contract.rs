//! Regression tests for the determinism-contract fixes in the simlint PR
//! (DESIGN.md §16): the crate-wide `partial_cmp(..).unwrap()` →
//! `f64::total_cmp` conversion, and the order-independence obligations the
//! `simlint::allow(unordered-iter)` annotations assert about
//! `AgentQueues::waiting_agents` consumers.

use justitia::sched::{AgentQueues, OrdF64, TaskInfo};
use justitia::workload::TaskId;

fn task(agent: u32, index: u32, seq: u64) -> TaskInfo {
    TaskInfo { id: TaskId { agent, index }, prompt_tokens: 8, predicted_decode: 4.0, seq }
}

#[test]
fn ordf64_is_total_and_nan_safe() {
    // Pre-PR this panicked ("NaN scheduling key"); a NaN produced mid-sweep
    // now sorts to a fixed slot instead of aborting a replay. Positive NaN
    // sorts above +inf in the IEEE-754 total order.
    let mut v = vec![OrdF64(3.0), OrdF64(f64::NAN), OrdF64(-1.0), OrdF64(f64::INFINITY)];
    v.sort(); // must not panic
    assert_eq!(v[0].0, -1.0);
    assert_eq!(v[1].0, 3.0);
    assert_eq!(v[2].0, f64::INFINITY);
    assert!(v[3].0.is_nan());
}

#[test]
fn ordf64_zero_signs_ordered_not_equal_case() {
    // total_cmp orders -0.0 < 0.0 (they remain == under PartialEq). The
    // schedulers only feed NaN-free keys where the old and new comparison
    // agree; this pins the one documented divergence so it is deliberate.
    assert_eq!(OrdF64(-0.0).cmp(&OrdF64(0.0)), std::cmp::Ordering::Less);
    assert_eq!(OrdF64(1.5).cmp(&OrdF64(1.5)), std::cmp::Ordering::Equal);
    assert_eq!(OrdF64(2.0).cmp(&OrdF64(1.0)), std::cmp::Ordering::Greater);
}

#[test]
fn min_agent_by_is_insertion_order_independent() {
    // `waiting_agents` iterates a HashMap (annotated): `min_agent_by` must
    // produce the same winner whatever order agents were registered in.
    // Keys collide on purpose so the agent-id tie-break decides.
    let keys = |a: u32| match a {
        7 => 1.0,
        3 => 1.0, // tie with 7 — lower id must win
        9 => 2.0,
        _ => 99.0,
    };
    let mut forward = AgentQueues::new();
    for (s, a) in [7u32, 3, 9, 12].iter().enumerate() {
        forward.push(task(*a, 0, s as u64));
    }
    let mut reverse = AgentQueues::new();
    for (s, a) in [12u32, 9, 3, 7].iter().enumerate() {
        reverse.push(task(*a, 0, s as u64));
    }
    assert_eq!(forward.min_agent_by(keys), Some(3));
    assert_eq!(reverse.min_agent_by(keys), Some(3));
}

#[test]
fn waiting_agents_set_is_stable_across_insertion_orders() {
    // Consumers must treat waiting_agents() as a set. Sorted collection of
    // the iterator is identical for permuted insertion orders.
    let ids = [5u32, 1, 9, 4, 2];
    let mut a = AgentQueues::new();
    let mut b = AgentQueues::new();
    for (s, &id) in ids.iter().enumerate() {
        a.push(task(id, 0, s as u64));
    }
    for (s, &id) in ids.iter().rev().enumerate() {
        b.push(task(id, 0, s as u64));
    }
    let mut va: Vec<u32> = a.waiting_agents().collect();
    let mut vb: Vec<u32> = b.waiting_agents().collect();
    va.sort_unstable();
    vb.sort_unstable();
    assert_eq!(va, vec![1, 2, 4, 5, 9]);
    assert_eq!(va, vb);
}

#[test]
fn float_sorts_survive_nan_without_panicking() {
    // The util stats path now uses total_cmp: a NaN input sorts to the
    // fixed last slot instead of crashing the whole sweep, so the median of
    // [1, 2, 3, NaN] is deterministically midway between 2 and 3.
    let p50 = justitia::util::stats::percentile(&[1.0, f64::NAN, 3.0, 2.0], 50.0);
    assert_eq!(p50, 2.5);
}
