//! Multi-replica cluster serving with cluster-level fair queuing.
//!
//! The paper serves task-parallel agents on *one* shared GPU. This module
//! shards the engine across N independent replicas — each with its own
//! [`BlockAllocator`](crate::kv::BlockAllocator) pool and its own Justitia
//! scheduler — behind a [`ClusterDispatcher`] that routes each arriving
//! agent to one replica under a pluggable [`Placement`] policy. Agents are
//! never split across replicas: an agent's tasks share KV-locality and its
//! fairness guarantee is per-agent, so the placement decision is the only
//! cluster-level degree of freedom.
//!
//! Fairness composition: with [`Placement::ClusterVtime`], each replica's
//! mirror virtual clock estimates where the agent's GPS-order finish tag
//! would land, and the dispatcher picks the replica minimizing it. Each
//! replica then pampers its agents in local GPS-finish order, so the
//! cluster-wide service order approximates a single N×M-capacity GPS server
//! — the same yardstick Theorem B.1 bounds Justitia against on one GPU.
//! [`Placement::PrefixAffinity`] adds cache locality on top: agents of one
//! shared-prefix family ([`crate::workload::PrefixGroup`]) are routed to the
//! replica whose radix tree ([`crate::prefix`]) already holds their prompt
//! chain, with cluster-vtime seeding families and breaking ties.
//!
//! Determinism: placement ties break toward the lowest replica index and
//! replicas are simulated independently, so a trace replay is exactly
//! reproducible; with one replica, every placement policy degenerates to the
//! single-engine path and reproduces its results bit for bit (asserted by
//! `rust/tests/test_cluster_determinism.rs`).

pub mod failure;
pub mod placement;

pub use failure::{AutoscalePolicy, ChurnEvent, ChurnKind, FailureSchedule};
pub use placement::Placement;

use crate::engine::exec::ExecBackend;
use crate::engine::{Engine, RecoveredAgent};
use crate::metrics::RunMetrics;
use crate::trace::{TraceEventKind, TraceRecorder, ENGINE_ROW};
use crate::workload::{AgentId, AgentSpec, Suite};
use placement::Placer;
use std::collections::{HashMap, VecDeque};

/// Replica-slot health during a churn run (DESIGN.md §14). Slots are
/// stable: a crashed or drained slot stays in the pool (ineligible, fresh
/// or idle engine) so later `Join` events can revive it by index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Health {
    /// Taking placements and stepping.
    Live,
    /// Graceful drain: stepping its in-flight work, no new placements.
    Draining,
    /// Departed: no placements, no stepping, awaiting a possible join.
    Down,
}

/// Routes agents across N independent engine replicas.
///
/// Two drive modes:
///
/// * **Trace replay** — [`run_suite`](ClusterDispatcher::run_suite) places
///   every agent in global arrival order, then runs each replica over its
///   sub-trace to completion (replicas are independent discrete-event
///   simulations; no cross-replica synchronization is needed).
/// * **Online serving** — [`submit`](ClusterDispatcher::submit) places one
///   agent against the replicas' *live* state and
///   [`step`](ClusterDispatcher::step) advances the laggard replica, which
///   keeps replica clocks loosely synchronized. The HTTP front-end drives
///   this mode.
pub struct ClusterDispatcher<B: ExecBackend> {
    replicas: Vec<Engine<B>>,
    placer: Placer,
    /// agent id → replica index, in placement order (a recovered agent's
    /// entry moves to its recovery replica).
    assignments: HashMap<AgentId, usize>,
    /// Crashed replicas' metrics and recorders, kept so cluster merges see
    /// the work done before each crash: (slot index, metrics, recorder).
    /// Empty unless a churn schedule ran — the immortal paths never touch
    /// it, so churn-off merges are byte-identical to pre-elasticity output.
    graveyard: Vec<(usize, RunMetrics, Option<TraceRecorder>)>,
}

impl<B: ExecBackend> ClusterDispatcher<B> {
    /// Build a dispatcher over pre-constructed replica engines.
    ///
    /// `capacity_tokens` is one replica's KV capacity M and `rate_scale` its
    /// nominal iterations/second — the same pair the replicas' Justitia
    /// schedulers were built with; the placement mirrors reuse them.
    pub fn new(
        replicas: Vec<Engine<B>>,
        placement: Placement,
        capacity_tokens: u64,
        rate_scale: f64,
    ) -> Self {
        assert!(!replicas.is_empty(), "cluster needs at least one replica");
        let n = replicas.len();
        ClusterDispatcher {
            replicas,
            placer: Placer::new(placement, n, capacity_tokens, rate_scale),
            assignments: HashMap::new(),
            graveyard: Vec::new(),
        }
    }

    /// Number of replicas.
    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// The active placement policy.
    pub fn placement(&self) -> Placement {
        self.placer.policy()
    }

    /// The replica an agent was routed to, if it has been placed.
    pub fn replica_of(&self, agent: AgentId) -> Option<usize> {
        self.assignments.get(&agent).copied()
    }

    /// Number of agents placed on each replica so far.
    pub fn assignment_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.replicas.len()];
        // simlint::allow(unordered-iter): commutative per-replica count, order-independent
        for &r in self.assignments.values() {
            counts[r] += 1;
        }
        counts
    }

    /// Direct access to one replica's engine (tests / introspection).
    pub fn replica(&self, r: usize) -> &Engine<B> {
        &self.replicas[r]
    }

    /// One replica's run metrics.
    pub fn replica_metrics(&self, r: usize) -> &RunMetrics {
        &self.replicas[r].metrics
    }

    /// Whether any replica still has admitted or waiting work.
    pub fn has_work(&self) -> bool {
        self.replicas.iter().any(|e| e.has_work())
    }

    /// Largest replica engine clock — the cluster makespan so far.
    pub fn makespan(&self) -> f64 {
        self.replicas.iter().map(|e| e.now()).fold(0.0, f64::max)
    }

    /// Online submission: place `spec` against the replicas' live state and
    /// submit it to the chosen replica at that replica's current clock.
    /// Returns the replica index.
    ///
    /// For [`Placement::ClusterVtime`] the live schedulers' own virtual
    /// clocks are consulted first
    /// ([`Scheduler::gps_finish_estimate`](crate::sched::Scheduler::gps_finish_estimate));
    /// policies without a virtual clock fall back to the dispatcher mirrors.
    pub fn submit(&mut self, spec: AgentSpec, predicted_cost: f64) -> usize {
        let agent = spec.id;
        let group = spec.prefix_group_id();
        let nows: Vec<f64> = self.replicas.iter().map(|e| e.now()).collect();
        // Probing every replica's scheduler is a per-replica scan; skip it
        // when the placer's decision is already determined (e.g. a
        // prefix-affinity family that has a home replica).
        let live: Vec<Option<f64>> = if self.placer.wants_live_estimates(group) {
            let placer = &self.placer;
            self.replicas
                .iter_mut()
                .zip(&nows)
                .enumerate()
                .map(|(r, (e, &now))| {
                    // Departed/draining slots take no placements, so their
                    // schedulers are never probed (a fresh post-crash engine
                    // would otherwise look infinitely attractive).
                    if placer.is_eligible(r) {
                        e.scheduler_mut().gps_finish_estimate(predicted_cost, now)
                    } else {
                        None
                    }
                })
                .collect()
        } else {
            vec![None; self.replicas.len()]
        };
        let r = self.placer.place(agent, predicted_cost, group, &nows, Some(&live));
        self.assignments.insert(agent, r);
        self.replicas[r].submit(spec, predicted_cost);
        r
    }

    /// Online stepping: advance the replica with the smallest engine clock
    /// among those with work (keeps clocks loosely synchronized so placement
    /// compares like with like). Returns that iteration's elapsed engine
    /// seconds, or 0.0 when no replica has work.
    pub fn step(&mut self) -> f64 {
        let mut pick: Option<usize> = None;
        for (r, e) in self.replicas.iter().enumerate() {
            if e.has_work() && pick.map(|p| e.now() < self.replicas[p].now()).unwrap_or(true) {
                pick = Some(r);
            }
        }
        match pick {
            Some(r) => self.replicas[r].step(),
            None => 0.0,
        }
    }

    /// Completion time of an agent on whichever replica owns it.
    pub fn agent_complete_time(&self, agent: AgentId) -> Option<f64> {
        let r = self.replica_of(agent)?;
        self.replicas[r].metrics.agent_complete_time(agent)
    }

    /// Replay a whole suite through the cluster: place every agent in global
    /// arrival order (calling `predict` exactly once per agent, preserving
    /// any stateful noise stream), then run each replica over its sub-trace
    /// with [`Engine::run_suite`]. Returns the cluster makespan.
    ///
    /// With a single replica this is *exactly* the single-engine
    /// [`Engine::run_suite`] call — same injection order, same clock
    /// alignment — so JCTs are bit-identical to a non-clustered run.
    pub fn run_suite<F: FnMut(&AgentSpec) -> f64>(
        &mut self,
        suite: &Suite,
        mut predict: F,
    ) -> f64 {
        // Phase 1: placement, in global arrival order.
        let (subs, costs) = self.place_suite(suite, &mut predict);
        // Phase 2: independent replica runs over the (already arrival-sorted,
        // globally-id'd) sub-traces. Suite::new would re-index ids, so the
        // sub-suites are constructed directly.
        for (r, agents) in subs.into_iter().enumerate() {
            if agents.is_empty() {
                continue;
            }
            let sub = Suite { agents };
            self.replicas[r].run_suite(&sub, |a| costs[&a.id]);
        }
        self.makespan()
    }

    /// Placement phase shared by the serial and parallel suite drivers:
    /// route every agent in global arrival order, recording assignments and
    /// the predicted cost (`predict` is called exactly once per agent, in
    /// suite order, preserving any stateful noise stream). Returns the
    /// per-replica sub-traces and the cost table.
    fn place_suite<F: FnMut(&AgentSpec) -> f64>(
        &mut self,
        suite: &Suite,
        predict: &mut F,
    ) -> (Vec<Vec<AgentSpec>>, HashMap<AgentId, f64>) {
        let n = self.replicas.len();
        let mut subs: Vec<Vec<AgentSpec>> = vec![Vec::new(); n];
        let mut costs: HashMap<AgentId, f64> = HashMap::with_capacity(suite.len());
        for a in &suite.agents {
            let cost = predict(a);
            let nows = vec![a.arrival; n];
            let r = self.placer.place(a.id, cost, a.prefix_group_id(), &nows, None);
            self.assignments.insert(a.id, r);
            costs.insert(a.id, cost);
            subs[r].push(a.clone());
        }
        (subs, costs)
    }

    /// [`run_suite`](Self::run_suite) with the phase-2 replica simulations
    /// spread over a [`ThreadPool`](crate::util::threadpool::ThreadPool) of
    /// `threads` workers. Replicas are *independent* discrete-event
    /// simulations over disjoint sub-traces, so running them concurrently
    /// changes nothing observable: placement (phase 1) stays serial in
    /// global arrival order, every engine computes exactly what it computes
    /// under the serial driver, engines are reinstalled in replica index
    /// order (`ThreadPool::map` preserves input order), and
    /// [`merged_metrics`](Self::merged_metrics) folds them in that same
    /// order — so the merged metrics are byte-identical for ANY thread
    /// count, 1 worker included (asserted by
    /// `tests/test_parallel_replica_determinism.rs`). `threads <= 1`
    /// delegates to the serial driver outright.
    pub fn run_suite_parallel<F>(&mut self, suite: &Suite, mut predict: F, threads: usize) -> f64
    where
        F: FnMut(&AgentSpec) -> f64,
        B: Send + 'static,
    {
        if threads <= 1 {
            return self.run_suite(suite, predict);
        }
        let (subs, costs) = self.place_suite(suite, &mut predict);
        let costs = std::sync::Arc::new(costs);
        // Engines move onto the pool and come back in input order.
        let replicas = std::mem::take(&mut self.replicas);
        let jobs: Vec<(Engine<B>, Vec<AgentSpec>)> = replicas.into_iter().zip(subs).collect();
        let pool = crate::util::threadpool::ThreadPool::new(threads);
        self.replicas = pool.map(jobs, move |(mut engine, agents)| {
            if !agents.is_empty() {
                let sub = Suite { agents };
                engine.run_suite(&sub, |a| costs[&a.id]);
            }
            engine
        });
        self.makespan()
    }

    /// Replay a suite under a deterministic churn schedule (DESIGN.md §14):
    /// replicas crash (losing all KV; in-flight agents recover through the
    /// recompute fold and re-place on the survivors), drain gracefully
    /// (finish in-flight work, take no placements, leave the pool), and
    /// join (revive the lowest departed slot or grow the pool), while an
    /// optional [`AutoscalePolicy`] reacts to live queue depth at fixed
    /// ticks. `spawn_replica` builds a fresh engine for crash replacements
    /// and pool growth. Returns the cluster makespan.
    ///
    /// An empty schedule delegates straight to
    /// [`run_suite`](Self::run_suite), so churn-off runs are byte-identical
    /// to the immortal-pool path by construction. Non-empty schedules switch
    /// to online submit+step driving (arrivals interleave with churn), which
    /// keeps replica clocks loosely synchronized so crash times mean the
    /// same thing on every replica.
    pub fn run_suite_churn<F, S>(
        &mut self,
        suite: &Suite,
        predict: F,
        schedule: &FailureSchedule,
        spawn_replica: S,
    ) -> f64
    where
        F: FnMut(&AgentSpec) -> f64,
        S: FnMut() -> Engine<B>,
    {
        self.run_churn(suite, predict, schedule, spawn_replica, false)
    }

    /// [`run_suite_churn`](Self::run_suite_churn) with foreknowledge: slots
    /// doomed to crash or drain are marked ineligible from t=0 (while at
    /// least one other slot stays eligible), so no work ever lands on a
    /// dying replica and nothing needs recovery. This is the oracle
    /// baseline the elasticity experiment measures degradation against —
    /// the best any dispatcher could do if failures were announced in
    /// advance.
    pub fn run_suite_churn_oracle<F, S>(
        &mut self,
        suite: &Suite,
        predict: F,
        schedule: &FailureSchedule,
        spawn_replica: S,
    ) -> f64
    where
        F: FnMut(&AgentSpec) -> f64,
        S: FnMut() -> Engine<B>,
    {
        self.run_churn(suite, predict, schedule, spawn_replica, true)
    }

    /// Shared churn driver. Event loop invariants (DESIGN.md §14):
    ///
    /// * The next *boundary* is the earliest of: next trace arrival, next
    ///   churn event, next autoscale tick (ticks only count while work
    ///   remains, else they would spin forever on an idle pool).
    /// * Between boundaries, the laggard live/draining replica with work
    ///   steps (ties break toward the lowest index) until every such
    ///   replica's clock reaches the boundary — the same laggard rule as
    ///   online [`step`](Self::step), so replica clocks stay loosely
    ///   synchronized and a crash at `t` means the same thing everywhere.
    /// * At one boundary time, order is fixed: churn events, then the
    ///   autoscale tick, then arrivals. Everything ties toward lower
    ///   replica / earlier list index, so the whole run is deterministic.
    fn run_churn<F, S>(
        &mut self,
        suite: &Suite,
        mut predict: F,
        schedule: &FailureSchedule,
        mut spawn_replica: S,
        oracle: bool,
    ) -> f64
    where
        F: FnMut(&AgentSpec) -> f64,
        S: FnMut() -> Engine<B>,
    {
        if schedule.is_empty() {
            return self.run_suite(suite, predict);
        }
        let mut events = schedule.events.clone();
        events.sort_by(|a, b| a.t.total_cmp(&b.t));
        let mut health = vec![Health::Live; self.replicas.len()];
        if oracle {
            for ev in &events {
                if let ChurnKind::Crash { replica } | ChurnKind::Drain { replica } = ev.kind {
                    if replica < self.replicas.len()
                        && self.placer.n_eligible() > 1
                        && self.placer.is_eligible(replica)
                    {
                        self.placer.set_ineligible(replica);
                    }
                }
            }
        }
        let mut ev_i = 0usize;
        let mut arr_i = 0usize;
        // Agents that arrived (or were recovered) while no replica was
        // eligible, parked until a join: (spec, cost, original arrival).
        let mut pending: VecDeque<(AgentSpec, f64, Option<f64>)> = VecDeque::new();
        let mut next_tick = schedule.autoscale.as_ref().map(|a| a.interval);
        loop {
            let work_ahead = arr_i < suite.len()
                || !pending.is_empty()
                || self
                    .replicas
                    .iter()
                    .zip(&health)
                    .any(|(e, &h)| h != Health::Down && e.has_work());
            let mut boundary = f64::INFINITY;
            if let Some(a) = suite.agents.get(arr_i) {
                boundary = boundary.min(a.arrival);
            }
            if let Some(ev) = events.get(ev_i) {
                boundary = boundary.min(ev.t);
            }
            if let (Some(t), true) = (next_tick, work_ahead) {
                boundary = boundary.min(t);
            }

            // Step live/draining replicas up to the boundary, laggard first.
            loop {
                let mut pick: Option<usize> = None;
                for (r, e) in self.replicas.iter().enumerate() {
                    if health[r] != Health::Down
                        && e.has_work()
                        && e.now() < boundary
                        && pick.map(|p| e.now() < self.replicas[p].now()).unwrap_or(true)
                    {
                        pick = Some(r);
                    }
                }
                let Some(r) = pick else { break };
                let elapsed = self.replicas[r].step();
                if elapsed == 0.0 && self.replicas[r].running_len() == 0 {
                    // Blocked with nothing running: nothing will unblock this
                    // replica before the boundary (mirror of the single-engine
                    // run_suite idle skip), so jump its clock there.
                    if boundary.is_finite() {
                        self.replicas[r].advance_clock(boundary);
                    } else {
                        panic!(
                            "stuck: replica {r} blocked with no future arrival, \
                             churn event, or autoscale tick"
                        );
                    }
                }
            }

            // Graceful-drain completion: a draining replica whose in-flight
            // work finished leaves the pool (nothing was lost).
            for r in 0..self.replicas.len() {
                if health[r] == Health::Draining && !self.replicas[r].has_work() {
                    health[r] = Health::Down;
                    self.placer.on_replica_down(r);
                }
            }

            if boundary.is_infinite() {
                assert!(
                    pending.is_empty(),
                    "stuck: {} agents pending with no eligible replica and no scheduled join",
                    pending.len()
                );
                break;
            }

            // Churn events due at this boundary. Stale targets (already-down
            // slots, out-of-range indices) are no-ops: random schedules may
            // name a slot twice.
            while events.get(ev_i).map(|e| e.t <= boundary + 1e-12).unwrap_or(false) {
                let ev = events[ev_i];
                ev_i += 1;
                match ev.kind {
                    ChurnKind::Crash { replica } => {
                        if replica < self.replicas.len() && health[replica] != Health::Down {
                            self.crash_replica(
                                replica,
                                ev.t,
                                &mut health,
                                &mut spawn_replica,
                                &mut pending,
                            );
                        }
                    }
                    ChurnKind::Drain { replica } => {
                        if replica < self.replicas.len() && health[replica] == Health::Live {
                            health[replica] = Health::Draining;
                            self.placer.set_ineligible(replica);
                            self.replicas[replica]
                                .trace_churn(ENGINE_ROW, TraceEventKind::ReplicaDrain);
                        }
                    }
                    ChurnKind::Join => {
                        self.join_replica(ev.t, &mut health, &mut spawn_replica, &mut pending);
                    }
                }
            }

            // Autoscale tick.
            if let (Some(tick), Some(pol)) = (next_tick, schedule.autoscale.as_ref()) {
                if work_ahead && tick <= boundary + 1e-12 {
                    let live: Vec<usize> = (0..self.replicas.len())
                        .filter(|&r| health[r] == Health::Live)
                        .collect();
                    let waiting = live
                        .iter()
                        .map(|&r| self.replicas[r].waiting_len())
                        .sum::<usize>()
                        + pending.len();
                    if (waiting as f64) > pol.up_queue * live.len() as f64
                        && live.len() < pol.max_replicas
                    {
                        self.join_replica(tick, &mut health, &mut spawn_replica, &mut pending);
                    } else if (waiting as f64) < pol.down_queue && live.len() > pol.min_replicas {
                        // Scale in: drain the highest-index live replica.
                        if let Some(&r) = live.last() {
                            health[r] = Health::Draining;
                            self.placer.set_ineligible(r);
                            self.replicas[r]
                                .trace_churn(ENGINE_ROW, TraceEventKind::ReplicaDrain);
                        }
                    }
                    next_tick = Some(tick + pol.interval);
                }
            }

            // Arrivals due at this boundary, in suite order. `predict` is
            // called exactly once per agent here, preserving any stateful
            // noise stream — same contract as place_suite.
            while suite
                .agents
                .get(arr_i)
                .map(|a| a.arrival <= boundary + 1e-12)
                .unwrap_or(false)
            {
                let a = suite.agents[arr_i].clone();
                arr_i += 1;
                let cost = predict(&a);
                if self.placer.n_eligible() == 0 {
                    pending.push_back((a, cost, None));
                } else {
                    let t = a.arrival;
                    self.place_churn(a, cost, t, None);
                }
            }
        }
        self.makespan()
    }

    /// Place one agent mid-churn-run at cluster time `t`: idle eligible
    /// replicas whose clocks lag `t` are advanced first so the submission is
    /// stamped at the true arrival time and the placer compares synchronized
    /// clocks. For a recovered agent, `orig_arrival` re-stamps the original
    /// arrival on the recovery replica (the graveyard-first merge order lets
    /// this entry win, keeping the JCT anchored where the agent really
    /// arrived) and emits a [`TraceEventKind::Recovered`] span marker.
    fn place_churn(
        &mut self,
        spec: AgentSpec,
        cost: f64,
        t: f64,
        orig_arrival: Option<f64>,
    ) -> usize {
        let id = spec.id;
        for (r, e) in self.replicas.iter_mut().enumerate() {
            if self.placer.is_eligible(r) && !e.has_work() && e.now() < t {
                e.advance_clock(t);
            }
        }
        let r = self.submit(spec, cost);
        // Submission stamps the replica clock, which can overshoot `t` by
        // one iteration on a busy replica; re-stamp the true arrival so
        // JCTs measure from when the agent really arrived at the cluster.
        self.replicas[r].metrics.on_agent_arrival(id, orig_arrival.unwrap_or(t));
        if orig_arrival.is_some() {
            self.replicas[r].trace_churn(id, TraceEventKind::Recovered);
        }
        r
    }

    /// Kill replica `r` at time `t`: salvage its incomplete agents through
    /// [`Engine::extract_for_recovery`] (the recompute fold), graveyard its
    /// metrics and recorder, swap a fresh engine into the slot (revivable by
    /// a later join), and re-place the survivors on the eligible pool with
    /// their virtual-time tags scaled to the remaining work.
    fn crash_replica(
        &mut self,
        r: usize,
        t: f64,
        health: &mut [Health],
        spawn_replica: &mut impl FnMut() -> Engine<B>,
        pending: &mut VecDeque<(AgentSpec, f64, Option<f64>)>,
    ) {
        // The stepping loop may have carried the replica slightly past the
        // event time within this boundary window; the crash lands at
        // whichever is later.
        let t = t.max(self.replicas[r].now());
        if self.replicas[r].now() < t {
            self.replicas[r].advance_clock(t);
        }
        let recovered = self.replicas[r].extract_for_recovery();
        let lost: u64 = recovered.iter().map(|a| a.lost_tokens).sum();
        self.replicas[r].trace_churn(ENGINE_ROW, TraceEventKind::ReplicaCrash);
        self.replicas[r].metrics.on_replica_lost(recovered.len() as u64, lost);
        let mut dead = std::mem::replace(&mut self.replicas[r], spawn_replica());
        health[r] = Health::Down;
        self.placer.on_replica_down(r);
        let trace = dead.take_trace();
        self.graveyard.push((r, std::mem::take(&mut dead.metrics), trace));
        for ra in recovered {
            if self.placer.n_eligible() == 0 {
                pending.push_back((ra.spec, ra.predicted_cost, Some(ra.arrival)));
            } else {
                self.place_churn(ra.spec, ra.predicted_cost, t, Some(ra.arrival));
            }
        }
    }

    /// One replica joins at time `t`: revive the lowest-index departed slot
    /// (a crashed slot already holds a fresh engine; a drain-departed slot
    /// reuses its old idle one — a warm restart, harmless since it kept no
    /// queued work), else grow the pool by one. Any parked agents place
    /// immediately.
    fn join_replica(
        &mut self,
        t: f64,
        health: &mut Vec<Health>,
        spawn_replica: &mut impl FnMut() -> Engine<B>,
        pending: &mut VecDeque<(AgentSpec, f64, Option<f64>)>,
    ) {
        let r = match health.iter().position(|&h| h == Health::Down) {
            Some(r) => {
                health[r] = Health::Live;
                self.placer.on_replica_up(r);
                r
            }
            None => {
                self.replicas.push(spawn_replica());
                health.push(Health::Live);
                self.placer.add_replica()
            }
        };
        if self.replicas[r].now() < t {
            self.replicas[r].advance_clock(t);
        }
        self.replicas[r].trace_churn(ENGINE_ROW, TraceEventKind::ReplicaJoin);
        while let Some((spec, cost, orig)) = pending.pop_front() {
            self.place_churn(spec, cost, t, orig);
        }
    }

    /// Cumulative churn counters summed across live replicas and the
    /// graveyard: (replicas_lost, recovered_agents, rescheduled_tokens).
    /// All zero on immortal-pool runs.
    pub fn churn_counters(&self) -> (u64, u64, u64) {
        let m = self.merged_metrics();
        (m.replicas_lost(), m.recovered_agents(), m.rescheduled_tokens())
    }

    /// Merge all replicas' metrics into one cluster-level [`RunMetrics`].
    /// Agent ids are globally unique, so the union is disjoint — except
    /// under churn, where a recovered agent appears in both its crashed
    /// replica's ledger (graveyarded) and its recovery replica's. Graveyard
    /// metrics merge *first* so the later, live-replica entries win merge's
    /// last-writer-wins maps: completion comes from the recovery replica and
    /// the JCT stays anchored at the original arrival (DESIGN.md §14).
    pub fn merged_metrics(&self) -> RunMetrics {
        let mut out = RunMetrics::new();
        for (_, m, _) in &self.graveyard {
            out.merge(m);
        }
        for e in &self.replicas {
            out.merge(&e.metrics);
        }
        out
    }

    /// Export every traced replica's flight recorder as one Chrome trace:
    /// one Perfetto process per replica ("replica N"), one thread row per
    /// agent within it (see [`crate::trace::chrome_trace`]). Returns `None`
    /// when no replica carries a recorder — tracing off, the default — so
    /// the HTTP `/trace` endpoint can 404 instead of serving an empty dump.
    pub fn merged_trace_chrome(&self) -> Option<crate::util::json::Json> {
        let n = self.replicas.len();
        // Live replicas keep pids 0..n (zero-churn output unchanged);
        // graveyarded recorders — a crashed slot's history up to the crash —
        // follow as extra processes with distinct pids.
        let mut labels: Vec<String> = (0..n).map(|r| format!("replica {r}")).collect();
        labels.extend(self.graveyard.iter().map(|(r, _, _)| format!("replica {r} (crashed)")));
        let mut parts: Vec<(u32, &str, &crate::trace::TraceRecorder)> = self
            .replicas
            .iter()
            .enumerate()
            .filter_map(|(r, e)| e.trace().map(|t| (r as u32, labels[r].as_str(), t)))
            .collect();
        for (gi, (_, _, tr)) in self.graveyard.iter().enumerate() {
            if let Some(t) = tr {
                parts.push(((n + gi) as u32, labels[n + gi].as_str(), t));
            }
        }
        if parts.is_empty() {
            None
        } else {
            Some(crate::trace::chrome_trace(&parts))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, Policy, WorkloadConfig};
    use crate::cost::CostModel;
    use crate::engine::exec::SimBackend;
    use crate::workload::test_support::simple_agent;
    use crate::workload::trace;

    fn engines(cfg: &Config, n: usize) -> Vec<Engine<SimBackend>> {
        (0..n)
            .map(|_| {
                let sched = crate::sched::build(Policy::Justitia, cfg.backend.kv_tokens, 1.0);
                Engine::new(cfg, sched, SimBackend::new(&cfg.backend))
            })
            .collect()
    }

    fn dispatcher(cfg: &Config, n: usize, p: Placement) -> ClusterDispatcher<SimBackend> {
        ClusterDispatcher::new(engines(cfg, n), p, cfg.backend.kv_tokens, 1.0)
    }

    fn small_suite(n_agents: usize, seed: u64) -> Suite {
        let wl = WorkloadConfig { n_agents, seed, ..Default::default() }.with_density(3.0);
        trace::build_suite(&wl)
    }

    #[test]
    fn one_replica_matches_single_engine_exactly() {
        let cfg = Config::default();
        let suite = small_suite(40, 11);
        let model = CostModel::MemoryCentric;

        let mut single = engines(&cfg, 1).pop().unwrap();
        single.run_suite(&suite, |a| model.agent_cost(a));
        let want = single.metrics.jcts();

        for p in Placement::ALL {
            let mut c = dispatcher(&cfg, 1, p);
            c.run_suite(&suite, |a| model.agent_cost(a));
            assert_eq!(c.merged_metrics().jcts(), want, "{p:?} diverged with one replica");
        }
    }

    #[test]
    fn multi_replica_completes_everything_deterministically() {
        let cfg = Config::default();
        let suite = small_suite(60, 5);
        let model = CostModel::MemoryCentric;
        for p in Placement::ALL {
            let run = || {
                let mut c = dispatcher(&cfg, 4, p);
                c.run_suite(&suite, |a| model.agent_cost(a));
                (c.merged_metrics().jcts(), c.assignment_counts())
            };
            let (jcts1, counts1) = run();
            let (jcts2, counts2) = run();
            assert_eq!(jcts1.len(), 60, "{p:?} dropped agents");
            assert_eq!(jcts1, jcts2, "{p:?} nondeterministic");
            assert_eq!(counts1, counts2);
            assert_eq!(counts1.iter().sum::<usize>(), 60);
        }
    }

    #[test]
    fn prefix_affinity_coalesces_families() {
        let mut cfg = Config::default();
        cfg.workload = WorkloadConfig { n_agents: 24, seed: 9, ..Default::default() }
            .with_density(3.0)
            .with_shared_prefix(4, 256);
        let suite = trace::build_suite(&cfg.workload);
        let mut c = dispatcher(&cfg, 4, Placement::PrefixAffinity);
        c.run_suite(&suite, |a| CostModel::MemoryCentric.agent_cost(a));
        // Every family lands on exactly one replica.
        let mut homes: HashMap<u64, usize> = HashMap::new();
        for a in &suite.agents {
            let g = a.prefix_group_id().unwrap();
            let r = c.replica_of(a.id).unwrap();
            assert_eq!(*homes.entry(g).or_insert(r), r, "family {g} split across replicas");
        }
        assert!(homes.len() >= 2, "suite should contain several families");
        assert_eq!(c.merged_metrics().completed_agents(), 24);
    }

    #[test]
    fn round_robin_spreads_counts_evenly() {
        let cfg = Config::default();
        let suite = small_suite(40, 3);
        let mut c = dispatcher(&cfg, 4, Placement::RoundRobin);
        c.run_suite(&suite, |a| CostModel::MemoryCentric.agent_cost(a));
        assert_eq!(c.assignment_counts(), vec![10, 10, 10, 10]);
    }

    #[test]
    fn scaling_out_reduces_jct_under_contention() {
        let cfg = Config::default();
        let suite = small_suite(80, 42);
        let model = CostModel::MemoryCentric;
        let avg = |n: usize| {
            let mut c = dispatcher(&cfg, n, Placement::ClusterVtime);
            c.run_suite(&suite, |a| model.agent_cost(a));
            c.merged_metrics().avg_jct()
        };
        let (one, four) = (avg(1), avg(4));
        assert!(four < one, "4 replicas ({four:.1}s) should beat 1 ({one:.1}s)");
    }

    #[test]
    fn online_submit_and_step_drain() {
        let cfg = Config::default();
        let mut c = dispatcher(&cfg, 2, Placement::ClusterVtime);
        let r0 = c.submit(simple_agent(0, 0.0, 2, 20, 10), 1000.0);
        let r1 = c.submit(simple_agent(1, 0.0, 1, 10, 5), 100.0);
        assert_eq!(c.replica_of(0), Some(r0));
        assert_eq!(c.replica_of(1), Some(r1));
        // Big agent saturates its replica's GPS; the small one goes elsewhere.
        assert_ne!(r0, r1);
        let mut guard = 0;
        while c.has_work() {
            c.step();
            guard += 1;
            assert!(guard < 10_000, "runaway");
        }
        let m = c.merged_metrics();
        assert_eq!(m.completed_agents(), 2);
        assert!(c.agent_complete_time(0).is_some() && c.agent_complete_time(1).is_some());
        assert!(c.makespan() > 0.0);
    }

    #[test]
    fn merged_trace_spans_replicas_and_is_absent_when_off() {
        let cfg = Config::default();
        let suite = small_suite(24, 7);
        let model = CostModel::MemoryCentric;
        // Tracing off (the default): nothing to merge.
        let mut c = dispatcher(&cfg, 2, Placement::RoundRobin);
        c.run_suite(&suite, |a| model.agent_cost(a));
        assert!(c.merged_trace_chrome().is_none());
        // Tracing on: one Perfetto process per replica.
        let mut cfg = cfg;
        cfg.trace = true;
        let mut c = dispatcher(&cfg, 2, Placement::RoundRobin);
        c.run_suite(&suite, |a| model.agent_cost(a));
        let json = c.merged_trace_chrome().expect("both replicas traced");
        let events = json.get("traceEvents").as_arr().unwrap();
        let processes: Vec<&str> = events
            .iter()
            .filter(|e| e.get("name").as_str() == Some("process_name"))
            .filter_map(|e| e.get("args").get("name").as_str())
            .collect();
        assert_eq!(processes, vec!["replica 0", "replica 1"]);
    }

    #[test]
    fn step_without_work_is_zero() {
        let cfg = Config::default();
        let mut c = dispatcher(&cfg, 2, Placement::RoundRobin);
        assert_eq!(c.step(), 0.0);
        assert!(!c.has_work());
    }

    fn spawner(cfg: &Config) -> impl FnMut() -> Engine<SimBackend> + '_ {
        move || {
            let sched = crate::sched::build(Policy::Justitia, cfg.backend.kv_tokens, 1.0);
            Engine::new(cfg, sched, SimBackend::new(&cfg.backend))
        }
    }

    #[test]
    fn empty_schedule_delegates_to_immortal_path() {
        let cfg = Config::default();
        let suite = small_suite(40, 11);
        let model = CostModel::MemoryCentric;
        let mut base = dispatcher(&cfg, 2, Placement::ClusterVtime);
        base.run_suite(&suite, |a| model.agent_cost(a));
        let mut churn = dispatcher(&cfg, 2, Placement::ClusterVtime);
        churn.run_suite_churn(&suite, |a| model.agent_cost(a), &FailureSchedule::none(), {
            spawner(&cfg)
        });
        assert_eq!(base.merged_metrics().jcts(), churn.merged_metrics().jcts());
        assert_eq!(churn.churn_counters(), (0, 0, 0));
    }

    #[test]
    fn crash_recovers_every_agent_deterministically() {
        let cfg = Config::default();
        let suite = small_suite(40, 11);
        let model = CostModel::MemoryCentric;
        let schedule = FailureSchedule::parse("crash@5:1").unwrap();
        let run = || {
            let mut c = dispatcher(&cfg, 2, Placement::ClusterVtime);
            c.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, spawner(&cfg));
            let m = c.merged_metrics();
            assert_eq!(m.completed_agents(), 40, "crash must not lose agents");
            assert_eq!(m.replicas_lost(), 1);
            (m.jcts(), m.recovered_agents(), m.rescheduled_tokens())
        };
        let (jcts1, rec1, tok1) = run();
        let (jcts2, rec2, tok2) = run();
        assert_eq!(jcts1, jcts2, "churn replay must be deterministic");
        assert_eq!((rec1, tok1), (rec2, tok2));
        assert!(rec1 > 0, "a mid-run crash should catch in-flight agents");
    }

    #[test]
    fn drain_strands_nothing_and_loses_nothing() {
        let cfg = Config::default();
        let suite = small_suite(40, 3);
        let model = CostModel::MemoryCentric;
        let schedule = FailureSchedule::parse("drain@4:1").unwrap();
        let mut c = dispatcher(&cfg, 2, Placement::RoundRobin);
        c.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, spawner(&cfg));
        let m = c.merged_metrics();
        assert_eq!(m.completed_agents(), 40, "drain must not strand agents");
        assert_eq!(c.churn_counters(), (0, 0, 0), "graceful drain loses nothing");
        // After the drain window every agent arriving later lands on slot 0.
        for a in &suite.agents {
            if a.arrival > 4.0 {
                assert_eq!(c.replica_of(a.id), Some(0), "drained slot took a placement");
            }
        }
    }

    #[test]
    fn join_grows_the_pool_and_takes_load() {
        let cfg = Config::default();
        let suite = small_suite(40, 5);
        let model = CostModel::MemoryCentric;
        let schedule = FailureSchedule::parse("join@2").unwrap();
        let mut c = dispatcher(&cfg, 1, Placement::ClusterVtime);
        c.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, spawner(&cfg));
        assert_eq!(c.n_replicas(), 2, "join on a full pool must grow it");
        let m = c.merged_metrics();
        assert_eq!(m.completed_agents(), 40);
        let counts = c.assignment_counts();
        assert!(counts[1] > 0, "the joined replica should take placements: {counts:?}");
    }

    #[test]
    fn crash_then_join_revives_the_same_slot() {
        let cfg = Config::default();
        let suite = small_suite(48, 13);
        let model = CostModel::MemoryCentric;
        let schedule = FailureSchedule::parse("crash@3:1,join@6").unwrap();
        let mut c = dispatcher(&cfg, 2, Placement::ClusterVtime);
        c.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, spawner(&cfg));
        assert_eq!(c.n_replicas(), 2, "join should revive the crashed slot, not grow");
        assert_eq!(c.merged_metrics().completed_agents(), 48);
        assert!(
            suite.agents.iter().any(|a| a.arrival > 6.0 && c.replica_of(a.id) == Some(1)),
            "revived slot should take post-join placements"
        );
    }

    #[test]
    fn autoscaler_joins_under_queue_pressure() {
        let cfg = Config::default();
        // Heavy burst on one replica with an eager autoscaler.
        let suite = small_suite(80, 42);
        let model = CostModel::MemoryCentric;
        let mut schedule = FailureSchedule::none();
        schedule.autoscale =
            Some(FailureSchedule::parse_autoscale("every=2,up=2,down=0,min=1,max=4").unwrap());
        let mut c = dispatcher(&cfg, 1, Placement::ClusterVtime);
        c.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, spawner(&cfg));
        assert!(c.n_replicas() > 1, "queue pressure should have triggered a join");
        assert_eq!(c.merged_metrics().completed_agents(), 80);
    }

    #[test]
    fn oracle_dispatcher_avoids_the_doomed_replica() {
        let cfg = Config::default();
        let suite = small_suite(40, 11);
        let model = CostModel::MemoryCentric;
        let schedule = FailureSchedule::parse("crash@5:1").unwrap();
        let mut c = dispatcher(&cfg, 2, Placement::ClusterVtime);
        c.run_suite_churn_oracle(&suite, |a| model.agent_cost(a), &schedule, spawner(&cfg));
        let m = c.merged_metrics();
        assert_eq!(m.completed_agents(), 40);
        assert_eq!(m.replicas_lost(), 1, "the replica still crashes under the oracle");
        assert_eq!(m.recovered_agents(), 0, "but nothing was placed on it");
        assert_eq!(c.assignment_counts()[1], 0);
    }

    #[test]
    fn churn_trace_marks_crash_and_recovery() {
        let mut cfg = Config::default();
        cfg.trace = true;
        let suite = small_suite(40, 11);
        let model = CostModel::MemoryCentric;
        let schedule = FailureSchedule::parse("crash@5:1").unwrap();
        let mut c = dispatcher(&cfg, 2, Placement::ClusterVtime);
        c.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, spawner(&cfg));
        let json = c.merged_trace_chrome().expect("tracing on");
        let events = json.get("traceEvents").as_arr().unwrap();
        let processes: Vec<&str> = events
            .iter()
            .filter(|e| e.get("name").as_str() == Some("process_name"))
            .filter_map(|e| e.get("args").get("name").as_str())
            .collect();
        assert_eq!(processes, vec!["replica 0", "replica 1", "replica 1 (crashed)"]);
        let names: Vec<&str> = events.iter().filter_map(|e| e.get("name").as_str()).collect();
        assert!(names.contains(&"replica_crash"), "crash transition must be traced");
        assert!(names.contains(&"recovered"), "recovered re-placement must be traced");
    }
}
