//! Ground-truth and noise-controlled oracles (Fig. 10 robustness study).
//!
//! "λ× means that the original cost information is scaled by a random factor
//! in [1/λ, λ] before being used by Justitia (when λ is 1 we directly use
//! the ground-truth)." The factor is log-uniform so over- and
//! under-prediction are symmetric in ratio space.

use crate::cost::CostModel;
use crate::util::rng::Rng;
use crate::workload::AgentSpec;

/// Noisy ground-truth oracle.
pub struct NoisyOracle {
    model: CostModel,
    lambda: f64,
    rng: Rng,
}

impl NoisyOracle {
    /// Oracle with noise scale `lambda` (1.0 = exact ground truth).
    pub fn new(model: CostModel, lambda: f64, seed: u64) -> Self {
        assert!(lambda >= 1.0, "lambda must be >= 1");
        NoisyOracle { model, lambda, rng: Rng::with_stream(seed, 0x04ac1e) }
    }

    /// The scheduled cost for an agent: truth × U_log[1/λ, λ]. "Truth" is
    /// the *arrival-visible* static DAG cost — dynamically spawned work is
    /// deliberately excluded, mirroring a real predictor that cannot see
    /// tasks which do not exist yet (the §4.2 online-correction loop is what
    /// closes that gap mid-flight).
    pub fn cost(&mut self, agent: &AgentSpec) -> f64 {
        let truth = self.model.agent_cost(agent);
        if self.lambda <= 1.0 {
            return truth;
        }
        let ln_l = self.lambda.ln();
        let factor = (self.rng.range_f64(-ln_l, ln_l)).exp();
        truth * factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::test_support::simple_agent;

    #[test]
    fn lambda_one_is_exact() {
        let mut o = NoisyOracle::new(CostModel::MemoryCentric, 1.0, 1);
        let a = simple_agent(0, 0.0, 2, 100, 50);
        let truth = CostModel::MemoryCentric.agent_cost(&a);
        assert_eq!(o.cost(&a), truth);
    }

    #[test]
    fn factors_bounded_by_lambda() {
        let mut o = NoisyOracle::new(CostModel::MemoryCentric, 3.0, 2);
        let a = simple_agent(0, 0.0, 1, 100, 50);
        let truth = CostModel::MemoryCentric.agent_cost(&a);
        for _ in 0..1000 {
            let c = o.cost(&a);
            assert!((truth / 3.0 - 1e-9..=truth * 3.0 + 1e-9).contains(&c));
        }
    }

    #[test]
    fn noise_is_ratio_symmetric() {
        let mut o = NoisyOracle::new(CostModel::MemoryCentric, 2.0, 3);
        let a = simple_agent(0, 0.0, 1, 100, 50);
        let truth = CostModel::MemoryCentric.agent_cost(&a);
        let mut log_sum = 0.0;
        let n = 20_000;
        for _ in 0..n {
            log_sum += (o.cost(&a) / truth).ln();
        }
        assert!((log_sum / n as f64).abs() < 0.01);
    }
}
